//! The serving simulation: one pipeline + one system (Harmonia or a
//! baseline) + one trace → a [`SimResult`].
//!
//! The simulator drives the *actual* shared control plane
//! ([`crate::sched::ControlPlane`]: routing, predicted slack, admission,
//! degradation, autoscaling — plus `StreamPolicy`) against a virtual
//! cluster whose component service times come from the calibrated
//! latency models — so the paper-scale experiments measure the same
//! policies a live deployment runs, at 32-GPU/1000-req scale on one box.
//! `SimWorld` itself holds only execution state (event queue, instances,
//! queues); every scheduling decision is delegated to the plane.

use std::collections::HashMap;
use std::time::Instant;

use crate::alloc::{AllocationPlan, FlowProblem};
use crate::coordinator::router::{InstanceState, RoutingPolicy};
use crate::coordinator::streaming::{StreamPolicy, StreamingMode, CHUNK_OVERHEAD, CHUNK_PREEMPT};
use crate::metrics::{CacheCounters, DisaggStats, Recorder, RunReport};
use crate::profile::models::{
    concurrency_slowdown, instance_concurrency, DecodeCostModel, GenBatching, GenPlacement,
    KvTransferModel, LatencyModel, CACHE_HIT_COST_FRAC, KV_PREFIX_HIT_COST_FRAC,
};
use crate::profile::{profile_graph_gen, Profile};
use crate::sched::{ControlPlane, PrioQueue, QueueDiscipline, SchedConfig};
use crate::spec::graph::{Adjacency, ComponentKind, ForkGroup, NodeId, PipelineGraph};
use crate::util::rng::Rng;
use crate::workload::TraceConfig;

use super::cluster::{Cluster, COLOCATION_SLOWDOWN};
use super::des::EventQueue;

/// Which serving system to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// Full Harmonia: LP allocation, load/state-aware routing, EDF+slack
    /// scheduling, managed streaming, periodic reallocation.
    Harmonia,
    /// LangChain-like: the whole pipeline replicated as monolithic
    /// processes; coarse-grained replication is the only scaling knob.
    LangChain,
    /// Haystack/Ray-like: per-component tasks, uniform static allocation,
    /// idle-first dispatch, FIFO, unmanaged streaming.
    Haystack,
}

impl SystemKind {
    pub fn name(self) -> &'static str {
        match self {
            SystemKind::Harmonia => "harmonia",
            SystemKind::LangChain => "langchain",
            SystemKind::Haystack => "haystack",
        }
    }
}

/// Feature toggles for the Fig. 14 ablation (all true = full Harmonia).
#[derive(Clone, Copy, Debug)]
pub struct AblationFlags {
    /// Periodic telemetry-driven re-solving (Resource Reallocation).
    pub realloc: bool,
    /// Load & state-aware routing (off → idle-first).
    pub routing: bool,
    /// Managed streaming granularity (off → the fixed-chunk baseline).
    pub stream_mgmt: bool,
    /// Deadline-aware scheduling (off → FIFO).
    pub slo_sched: bool,
}

impl Default for AblationFlags {
    fn default() -> Self {
        AblationFlags { realloc: true, routing: true, stream_mgmt: true, slo_sched: true }
    }
}

/// Simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub system: SystemKind,
    pub ablation: AblationFlags,
    pub trace: TraceConfig,
    pub seed: u64,
    /// Base streaming mode on streamable edges (Harmonia with
    /// `stream_mgmt` upgrades this to Managed).
    pub streaming: StreamingMode,
    /// Multiplicative error applied to deploy-time profiling priors (the
    /// paper: offline estimates deviate when the workload shifts);
    /// runtime reallocation corrects it.
    pub profile_bias: f64,
    /// Per-dispatch controller decision overhead (≈2 ms, §3.3).
    pub controller_overhead: f64,
    /// Cold-start delay for newly launched instances (s).
    pub cold_start: f64,
    /// Hard stop (simulated seconds).
    pub max_sim_time: f64,
    /// Overload-control knobs (admission / degradation / rekey). All off
    /// by default: the stock plane admits everything and golden traces
    /// replay bit-identically.
    pub sched: SchedConfig,
    /// Generator batching model. `Legacy` (the default) keeps the
    /// aggregate calibrated latency model and replays golden traces
    /// bit-identically; `Static` models run-to-completion batches at
    /// decode-step granularity (a short request co-batched with a long
    /// one finishes when the long one does); `Continuous` admits and
    /// retires requests between decode steps via the occupancy-aware
    /// [`DecodeCostModel`]. Non-legacy modes also record TTFT and
    /// per-token latency into [`RunReport::gen`], and the LP priors /
    /// admission slack predictions are re-profiled under the same model.
    pub gen_batching: GenBatching,
    /// Generator placement. `Collocated` (the default) serves prefill
    /// and decode from one pool and replays golden traces bit-identically;
    /// `Disaggregated` splits every generator into a prefill pool and a
    /// decode pool joined by an explicit KV-transfer handoff event (the
    /// RAGO-style split), with the LP choosing the pool sizes.
    pub gen_placement: GenPlacement,
    /// KV-transfer fabric between the pools (Disaggregated only).
    pub kv_transfer: KvTransferModel,
    /// Modeled KV prefix-cache hit probability over the workload's
    /// retrieved-context segment chains (Disaggregated only; 0 = no
    /// prefix cache, and no randomness is consumed). Use
    /// [`crate::profile::models::zipf_hit_rate`] on the context pool to
    /// derive it from a Zipf repeat distribution, mirroring how
    /// `cached_vanilla_rag` prices the query cache.
    pub kv_prefix_hit_rate: f64,
}

impl SimConfig {
    pub fn new(system: SystemKind, trace: TraceConfig, seed: u64) -> Self {
        SimConfig {
            system,
            ablation: AblationFlags::default(),
            trace,
            seed,
            streaming: StreamingMode::FixedChunk(0.15),
            // Deploy-time profiling is representative by default (the
            // paper profiles at startup); Fig. 14 sets a bias explicitly
            // to study the reallocation mechanism under workload shift.
            profile_bias: 1.0,
            controller_overhead: 2.0e-3,
            cold_start: 2.0,
            max_sim_time: 3600.0,
            sched: SchedConfig::default(),
            gen_batching: GenBatching::Legacy,
            gen_placement: GenPlacement::Collocated,
            kv_transfer: KvTransferModel::default(),
            kv_prefix_hit_rate: 0.0,
        }
    }
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub report: RunReport,
    /// Mean wall-clock seconds of controller decision code per dispatch
    /// (the Fig. 13 measurement — real time of the real policy code).
    pub controller_decision_secs: f64,
    pub controller_decisions: u64,
    /// LP solve wall-times (Fig. 12 / §4.3).
    pub lp_solve_secs: Vec<f64>,
    /// Committed reallocation count.
    pub reallocations: usize,
    /// Final up-instance counts per component name.
    pub final_instances: HashMap<String, usize>,
    /// Stateful router bindings still held when the run ended — the
    /// slot-leak audit's probe: 0 whenever every request reached a
    /// terminal path (completion, shed, or cancelled fork loser).
    pub residual_bindings: usize,
    /// Total events popped by the DES core — the perf bench's
    /// events/sec numerator.
    pub events: u64,
    /// Schedules that asked for a past time and were clamped to the
    /// clock (see [`super::des::EventQueue::clamped`]). A healthy model
    /// never produces one; tests pin this at 0.
    pub clamped: u64,
}

#[derive(Clone, Debug)]
enum Ev {
    Arrival(usize),
    /// Request (or fork-branch subtask, `branch` > 0) runnable at a
    /// node. `earliest_finish` > 0 carries the streaming floor (cannot
    /// finish before upstream's last chunk); `stream_chunks` > 0 adds
    /// per-chunk preemption busy-time downstream.
    Dispatch { req: usize, node: NodeId, branch: u32, earliest_finish: f64, stream_chunks: f64 },
    Finish { req: usize, node: NodeId, inst: usize, service: f64, branch: u32 },
    /// Disaggregated generator, phase boundary 1: the prefill pool
    /// finished a request's prefill pass; its KV pages go on the wire.
    /// `decode`/`transfer` were priced at prefill start; `total` is the
    /// combined service attribution for the plane.
    PrefillFinish {
        req: usize,
        node: NodeId,
        inst: usize,
        branch: u32,
        decode: f64,
        transfer: f64,
        total: f64,
        earliest_finish: f64,
    },
    /// Phase boundary 2: the KV transfer landed on the decode side; the
    /// request is admitted to (or queued for) the decode pool.
    KvHandoff { req: usize, node: NodeId, branch: u32, decode: f64, total: f64, earliest_finish: f64 },
    /// Phase boundary 3: the decode pool emitted the request's last
    /// token; the visit completes and the pipeline advances.
    DecodeFinish { req: usize, node: NodeId, inst: usize, branch: u32, total: f64 },
    ControlTick,
    InstanceUp { node: NodeId, inst: usize },
}

/// One unit of decode-pool work under disaggregated placement: the
/// request's own decode span (priced at prefill start), waiting for a
/// decode slot after its KV handoff landed.
#[derive(Clone, Debug)]
struct DecodeItem {
    req: usize,
    branch: u32,
    /// Decode-side service span.
    decode: f64,
    /// Combined prefill + transfer + decode attribution for the plane.
    total: f64,
    enqueued_at: f64,
    earliest_finish: f64,
}

/// Barrier state of one in-flight fork: which sibling branches are still
/// out, when the completed ones arrived, and which branch context the
/// join continues on once released.
#[derive(Clone, Debug)]
struct JoinCell {
    join: NodeId,
    /// Arrivals that release the barrier (`branches` for All, k for
    /// FirstK(k)).
    need: usize,
    /// Branch context of the fork node itself (0 = trunk; an enclosing
    /// branch id for nested forks) — the join resumes on it.
    parent: u32,
    /// Branch ids not yet arrived.
    outstanding: Vec<u32>,
    /// Virtual arrival times of completed branches (join-wait stats).
    arrivals: Vec<f64>,
}

struct SimInstance {
    slots: usize,
    active: usize,
    queue: PrioQueue<QueuedItem>,
    up: bool,
    colocated: bool,
    /// Outstanding stateful requests expected to return here.
    expected_reentries: f64,
}

#[derive(Clone, Debug)]
struct QueuedItem {
    req: usize,
    /// Fork-branch subtask id (0 = the request's trunk).
    branch: u32,
    enqueued_at: f64,
    earliest_finish: f64,
    /// Number of streamed chunks feeding this stage (0 = not streamed).
    stream_chunks: f64,
}

/// Arena entry for one live fork-branch subtask: its rng stream, the
/// join cell it reports to, and its cancellation mark. Lives inside the
/// owning [`SimReq`] — forks are shallow (a handful of branches per
/// request), so linear scans over this small vec replace what used to
/// be four `(req, branch)`-keyed global `HashMap`s rehashing on every
/// hot-path event.
struct BranchState {
    id: u32,
    /// The join cell this branch reports to (index into `SimReq::cells`).
    cell: u32,
    /// Deterministic per-branch rng stream (forked from the parent
    /// stream in declaration order at fork time).
    rng: Rng,
    /// FirstK loser cancelled by a released barrier. Queued items are
    /// discarded lazily when popped; in-service ones free their slot at
    /// Finish and go no further.
    cancelled: bool,
}

struct SimReq {
    arrival: f64,
    deadline: Option<f64>,
    features: crate::profile::models::RequestFeatures,
    rng: Rng,
    done: bool,
    /// TTFT already recorded (first generator visit only).
    ttft_done: bool,
    /// Branch-id allocator (fork subtasks; 0 is the trunk).
    next_branch: u32,
    /// Join-cell allocator (one per executed fork).
    next_cell: u32,
    /// Live fork-branch subtasks (empty outside forks).
    branches: Vec<BranchState>,
    /// In-flight fork barriers, keyed by cell id.
    cells: Vec<(u32, JoinCell)>,
    /// Hops already dispatched downstream via streaming.
    pending_stream: Vec<NodeId>,
    /// Branches pre-sampled at service start (streamable node, hop not
    /// streamed): Finish must honor the already-decided control flow.
    pre_sampled: Vec<(NodeId, NodeId)>,
}

impl SimReq {
    /// Per-branch rng stream: the trunk uses the request's own stream,
    /// fork subtasks use theirs (forked deterministically at fork time)
    /// so sibling branches never perturb each other's draws regardless
    /// of event interleaving.
    fn rng_mut(&mut self, branch: u32) -> &mut Rng {
        if branch == 0 {
            &mut self.rng
        } else {
            let b =
                self.branches.iter_mut().find(|b| b.id == branch).expect("live branch rng");
            &mut b.rng
        }
    }

    /// Drop a subtask's branch bookkeeping (join arrival, cancellation,
    /// or lazy discard of a queued loser). No-op for the trunk or an
    /// already-purged branch.
    fn purge_branch(&mut self, branch: u32) {
        if let Some(i) = self.branches.iter().position(|b| b.id == branch) {
            self.branches.swap_remove(i);
        }
    }

    fn is_cancelled(&self, branch: u32) -> bool {
        self.branches.iter().any(|b| b.id == branch && b.cancelled)
    }

    /// Consume a cancellation: when `branch` is a marked FirstK loser,
    /// drop its whole arena entry (mark, cell link, rng) and report
    /// true. The trunk is never cancelled.
    fn take_cancelled(&mut self, branch: u32) -> bool {
        if let Some(i) = self.branches.iter().position(|b| b.id == branch && b.cancelled) {
            self.branches.swap_remove(i);
            true
        } else {
            false
        }
    }

    fn cancel_branch(&mut self, branch: u32) {
        if let Some(b) = self.branches.iter_mut().find(|b| b.id == branch) {
            b.cancelled = true;
        }
    }

    /// The join cell `branch` reports to, if it is a live fork subtask.
    fn cell_of(&self, branch: u32) -> Option<u32> {
        self.branches.iter().find(|b| b.id == branch).map(|b| b.cell)
    }

    fn cell(&self, cell: u32) -> Option<&JoinCell> {
        self.cells.iter().find(|(id, _)| *id == cell).map(|(_, c)| c)
    }

    fn cell_mut(&mut self, cell: u32) -> Option<&mut JoinCell> {
        self.cells.iter_mut().find(|(id, _)| *id == cell).map(|(_, c)| c)
    }

    fn take_cell(&mut self, cell: u32) -> Option<JoinCell> {
        self.cells
            .iter()
            .position(|(id, _)| *id == cell)
            .map(|i| self.cells.swap_remove(i).1)
    }

    fn remove_pending_stream(&mut self, node: NodeId) -> bool {
        if let Some(i) = self.pending_stream.iter().position(|&n| n == node) {
            self.pending_stream.swap_remove(i);
            true
        } else {
            false
        }
    }

    fn remove_pre_sampled(&mut self, node: NodeId) -> Option<NodeId> {
        self.pre_sampled
            .iter()
            .position(|&(n, _)| n == node)
            .map(|i| self.pre_sampled.swap_remove(i).1)
    }
}

/// The simulation world. Execution state only — policy lives in `plane`.
pub struct SimWorld {
    cfg: SimConfig,
    graph: PipelineGraph,
    q: EventQueue<Ev>,
    reqs: Vec<SimReq>,
    /// Instance pools, indexed by `NodeId.0` (dense: every node has an
    /// entry, non-work nodes simply stay empty). Node ids are vec
    /// indices by construction, so the hot path never hashes.
    instances: Vec<Vec<SimInstance>>,
    /// The shared scheduling control plane (routing, slack, admission,
    /// degradation, telemetry, autoscaling) — the same object the live
    /// controller drives, here ticked by the virtual clock.
    plane: ControlPlane,
    prior: Profile,
    recorder: Recorder,
    cluster: Cluster,
    stream_policy: StreamPolicy,
    /// Central per-component queues (the controller holds queued work;
    /// instances pull — EDF reorders across the whole component, like the
    /// paper's centralized scheduler). Stateful-bound items still use the
    /// bound instance's local queue. Indexed by `NodeId.0`.
    node_queues: Vec<PrioQueue<QueuedItem>>,
    /// Cached adjacency index (edge ids per node, edge order) — the DES
    /// samples branches every hop; no per-hop O(E) scans.
    adj: Adjacency,
    /// Fork node → resolved fork group, indexed by `NodeId.0`.
    fork_map: Vec<Option<ForkGroup>>,
    /// Scratch buffer for the router's per-dispatch instance snapshot
    /// (reused across dispatches; the hot path allocates nothing).
    route_states: Vec<InstanceState>,
    /// Pre-rendered "<name>.prefill" / "<name>.decode" component labels,
    /// indexed by `NodeId.0` (built only under Disaggregated placement —
    /// the recorder used to pay a `format!` per visit for these).
    prefill_names: Vec<String>,
    decode_names: Vec<String>,
    decision_time: f64,
    decisions: u64,
    monolithic: bool,
    completed: usize,
    /// Requests shed at admission (terminal, like completion).
    shed: usize,
    /// Modeled query-cache hits/misses (components with
    /// `cache_hit_rate > 0`); surfaces in `RunReport::cache`.
    cache_counters: CacheCounters,
    /// Decode-pool instances for disaggregated generator nodes
    /// (`instances` then holds the prefill pool). All empty under
    /// Collocated. Indexed by `NodeId.0`.
    decode_instances: Vec<Vec<SimInstance>>,
    /// Central decode-pool queues: handed-off requests waiting for a
    /// decode slot (FIFO — handoff order is arrival order at this
    /// stage). Indexed by `NodeId.0`.
    decode_queues: Vec<PrioQueue<DecodeItem>>,
    /// Modeled KV prefix-cache hits/misses (Disaggregated only);
    /// surfaces in `RunReport::disagg.kv_prefix`.
    kv_counters: CacheCounters,
    /// KV handoff count and cumulative transfer seconds.
    handoffs: u64,
    transfer_total: f64,
}

impl SimWorld {
    pub fn new(graph: PipelineGraph, cfg: SimConfig) -> SimWorld {
        let trace = cfg.trace.generate(cfg.seed);
        let mut rng = Rng::new(cfg.seed ^ 0xDEAD);
        let reqs: Vec<SimReq> = trace
            .requests
            .iter()
            .map(|r| SimReq {
                arrival: r.arrival,
                deadline: r.deadline,
                features: r.features,
                rng: rng.fork(),
                done: false,
                ttft_done: false,
                next_branch: 0,
                next_cell: 0,
                branches: Vec::new(),
                cells: Vec::new(),
                pending_stream: Vec::new(),
                pre_sampled: Vec::new(),
            })
            .collect();

        let cluster = Cluster::paper_testbed();
        let budgets = cluster.budgets();

        // Deploy-time profile. `profile_bias` models the paper's workload
        // drift: what the profiling sample gets wrong in conditional
        // pipelines is the *branch mix* (p_{i,j}) — e.g. the fraction of
        // low-relevance queries, or Self-RAG's loop re-entry rate. We skew
        // every branching node's secondary-edge priors down by bias² and
        // renormalize; linear pipelines (V-RAG) have no branches and stay
        // unbiased, matching the paper's "online resource management
        // provides negligible contribution for V-RAG".
        let mut prior = profile_graph_gen(&graph, 400, cfg.seed ^ 0xBEEF, cfg.gen_batching);
        if cfg.profile_bias != 1.0 {
            let b2 = cfg.profile_bias * cfg.profile_bias;
            for node in &graph.nodes {
                // Only probabilistic branch mixes drift; fork edges are
                // structural (always 1 per branch) and stay unbiased.
                let out: Vec<usize> = graph
                    .edges
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.from == node.id && !e.is_fork())
                    .map(|(i, _)| i)
                    .collect();
                if out.len() < 2 {
                    continue;
                }
                let primary = *out
                    .iter()
                    .max_by(|&&a, &&b| prior.edge_probs[a].total_cmp(&prior.edge_probs[b]))
                    .unwrap();
                for &i in &out {
                    if i != primary {
                        prior.edge_probs[i] /= b2;
                    }
                }
                let sum: f64 = out.iter().map(|&i| prior.edge_probs[i]).sum();
                for &i in &out {
                    prior.edge_probs[i] /= sum;
                }
            }
        }

        let monolithic = cfg.system == SystemKind::LangChain;
        let plan = match cfg.system {
            // `with_placement` with the default Collocated placement is
            // the identity formulation (pinned in `alloc::flow` tests),
            // so this call is unconditional.
            SystemKind::Harmonia => FlowProblem::new(&graph, &prior, budgets)
                .with_placement(cfg.gen_placement, cfg.kv_transfer, cfg.kv_prefix_hit_rate)
                .solve()
                .expect("allocation feasible"),
            _ => AllocationPlan::uniform(&graph, &cluster.budgets()),
        };

        let routing = match (cfg.system, cfg.ablation.routing) {
            (SystemKind::Harmonia, true) => RoutingPolicy::LoadStateAware,
            (SystemKind::Harmonia, false) => RoutingPolicy::IdleFirst,
            (SystemKind::Haystack, _) => RoutingPolicy::IdleFirst,
            (SystemKind::LangChain, _) => RoutingPolicy::RoundRobin,
        };
        let discipline = if cfg.system == SystemKind::Harmonia && cfg.ablation.slo_sched {
            QueueDiscipline::LeastSlack
        } else {
            QueueDiscipline::Fifo
        };

        // Placement-aware slack priors: under disaggregation the
        // generator's effective per-visit service is repriced (discounted
        // prefill + KV transfer + decode), so admission doesn't over-shed
        // when only the decode pool is saturated. Under Collocated this
        // is exactly `prior.mean_service` — bit-identical slack keys.
        let plane_priors =
            prior.placement_priors(cfg.gen_placement, &cfg.kv_transfer, cfg.kv_prefix_hit_rate);
        let plane = ControlPlane::new(
            &graph,
            &plane_priors,
            routing,
            discipline,
            cfg.sched,
            10.0,
        );
        let n_nodes = graph.nodes.len();
        // One analysis pass supplies the DES's dispatch indices: the
        // adjacency (per-hop branch sampling) and the dense fork map.
        let az = graph.analyze();
        let (adj, fork_map) = (az.adj, az.fork_map);
        let (prefill_names, decode_names) = if cfg.gen_placement == GenPlacement::Disaggregated
        {
            (
                graph.nodes.iter().map(|n| format!("{}.prefill", n.name)).collect(),
                graph.nodes.iter().map(|n| format!("{}.decode", n.name)).collect(),
            )
        } else {
            (Vec::new(), Vec::new())
        };
        let discipline = plane.discipline;
        let mut world = SimWorld {
            plane,
            instances: (0..n_nodes).map(|_| Vec::new()).collect(),
            q: EventQueue::new(),
            reqs,
            recorder: Recorder::new(),
            cluster,
            stream_policy: StreamPolicy::default(),
            node_queues: (0..n_nodes).map(|_| PrioQueue::new(discipline)).collect(),
            adj,
            fork_map,
            route_states: Vec::new(),
            prefill_names,
            decode_names,
            decision_time: 0.0,
            decisions: 0,
            monolithic,
            completed: 0,
            shed: 0,
            cache_counters: CacheCounters::new(),
            decode_instances: (0..n_nodes).map(|_| Vec::new()).collect(),
            decode_queues: (0..n_nodes)
                .map(|_| PrioQueue::new(QueueDiscipline::Fifo))
                .collect(),
            kv_counters: CacheCounters::new(),
            handoffs: 0,
            transfer_total: 0.0,
            prior,
            graph,
            cfg,
        };
        world.provision_initial(&plan);
        world
    }

    fn provision_initial(&mut self, plan: &AllocationPlan) {
        if self.monolithic {
            // LangChain: the unit of deployment is the whole pipeline;
            // replicas = how many full bundles fit in the cluster.
            let mut demands: HashMap<crate::spec::graph::ResourceKind, f64> = HashMap::new();
            for n in self.graph.work_nodes() {
                for &(k, d) in &n.resources {
                    *demands.entry(k).or_insert(0.0) += d;
                }
            }
            let bundle: Vec<_> = demands.into_iter().collect();
            let mut replicas = Vec::new();
            while self.cluster.place(&bundle, true).is_some() {
                replicas.push(SimInstance {
                    slots: 4, // concurrent requests inside one process
                    active: 0,
                    queue: PrioQueue::new(self.plane.discipline),
                    up: true,
                    colocated: false,
                    expected_reentries: 0.0,
                });
                if replicas.len() >= 64 {
                    break;
                }
            }
            assert!(!replicas.is_empty(), "cluster hosts at least one replica");
            self.instances[self.graph.source.0] = replicas;
            return;
        }
        let node_ids: Vec<NodeId> = self.graph.work_nodes().map(|n| n.id).collect();
        for id in node_ids {
            // Sharded nodes deploy in complete replica *sets* (one replica
            // of every shard); `units` counts those, matching what one
            // simulated instance actually serves.
            let count = plan.units(id).max(1);
            if self.disagg_node(id) {
                // Split the generator's deployable units between the
                // prefill and decode pools: the LP's explicit split when
                // it solved one, else the profile's prefill/decode time
                // ratio. Each pool keeps ≥ 1 instance and the pair never
                // exceeds the node's total allocation (the LP's per-pool
                // ceils may otherwise sum one over).
                let (lp_pre, lp_dec) = plan.pools(id).unwrap_or_else(|| {
                    let pf = self
                        .prior
                        .gen_split
                        .get(&id)
                        .map(|s| (s.prefill / s.total().max(1e-12)).clamp(0.0, 1.0))
                        .unwrap_or(0.2);
                    let pre = (count as f64 * pf).round() as usize;
                    (pre, count.saturating_sub(pre))
                });
                let n_pre = lp_pre.clamp(1, count.saturating_sub(1).max(1));
                let n_dec = lp_dec.clamp(1, (count - n_pre).max(1));
                self.instances[id.0] = (0..n_pre).map(|_| self.make_instance(id)).collect();
                self.decode_instances[id.0] =
                    (0..n_dec).map(|_| self.make_instance(id)).collect();
            } else {
                self.instances[id.0] = (0..count).map(|_| self.make_instance(id)).collect();
            }
        }
    }

    /// Is `node` a generator served by split prefill/decode pools this
    /// run? (Monolithic replicas inline the whole pipeline — placement
    /// doesn't apply.)
    fn disagg_node(&self, node: NodeId) -> bool {
        !self.monolithic
            && self.cfg.gen_placement == GenPlacement::Disaggregated
            && matches!(self.graph.node(node).kind, ComponentKind::Generator)
    }

    fn make_instance(&mut self, node: NodeId) -> SimInstance {
        let spec = self.graph.node(node);
        // One simulated instance of a sharded component is a complete
        // scatter-gather unit — one replica of every shard — so it
        // occupies `shards` per-replica resource bundles.
        let mut demands = spec.resources.clone();
        if spec.shards > 1 {
            for d in demands.iter_mut() {
                d.1 *= spec.shards as f64;
            }
        }
        let placement = self.cluster.place(&demands, spec.kind.gpu_bound());
        SimInstance {
            slots: instance_concurrency(&spec.kind),
            active: 0,
            queue: PrioQueue::new(self.plane.discipline),
            up: true,
            colocated: placement.map(|p| p.colocated).unwrap_or(false),
            expected_reentries: 0.0,
        }
    }

    /// Run to completion.
    pub fn run(mut self) -> SimResult {
        for i in 0..self.reqs.len() {
            let t = self.reqs[i].arrival;
            self.q.schedule(t, Ev::Arrival(i));
        }
        self.q.schedule(1.0, Ev::ControlTick);
        while let Some((now, ev)) = self.q.next() {
            if now > self.cfg.max_sim_time {
                break;
            }
            match ev {
                Ev::Arrival(i) => {
                    self.recorder.on_arrival(now);
                    let entry =
                        if self.monolithic { self.graph.source } else { self.first_node() };
                    if self.admit_arrival(i, entry, now) {
                        // A fork at the pipeline entry fans the request
                        // out immediately (hybrid retrieval: dense ∥ web
                        // from the first hop).
                        if !self.monolithic && self.fork_map[self.graph.source.0].is_some() {
                            self.do_fork(i, self.graph.source, 0);
                        } else {
                            self.q.schedule_in(
                                self.cfg.controller_overhead,
                                Ev::Dispatch {
                                    req: i,
                                    node: entry,
                                    branch: 0,
                                    earliest_finish: 0.0,
                                    stream_chunks: 0.0,
                                },
                            );
                        }
                    }
                }
                Ev::Dispatch { req, node, branch, earliest_finish, stream_chunks } => {
                    self.on_dispatch(req, node, branch, earliest_finish, stream_chunks)
                }
                Ev::Finish { req, node, inst, service, branch } => {
                    self.on_finish(req, node, inst, service, branch)
                }
                Ev::PrefillFinish {
                    req,
                    node,
                    inst,
                    branch,
                    decode,
                    transfer,
                    total,
                    earliest_finish,
                } => self
                    .on_prefill_finish(req, node, inst, branch, decode, transfer, total, earliest_finish),
                Ev::KvHandoff { req, node, branch, decode, total, earliest_finish } => {
                    self.on_kv_handoff(req, node, branch, decode, total, earliest_finish)
                }
                Ev::DecodeFinish { req, node, inst, branch, total } => {
                    self.on_decode_finish(req, node, inst, branch, total)
                }
                Ev::ControlTick => {
                    self.on_control_tick();
                    if self.completed + self.shed < self.reqs.len() {
                        self.q.schedule_in(1.0, Ev::ControlTick);
                    }
                }
                Ev::InstanceUp { node, inst } => {
                    self.on_instance_up(node, inst);
                }
            }
            if self.completed + self.shed == self.reqs.len() {
                break;
            }
        }
        let cache_snap = self.cache_counters.snapshot();
        if cache_snap.lookups() > 0 {
            self.recorder.set_cache(cache_snap);
        }
        if self.cfg.sched.enabled() {
            self.recorder.set_sched(self.plane.counters.snapshot());
        }
        // Disaggregation section: only a run that actually split the
        // generator attaches it — Collocated reports (and golden traces)
        // carry no trace of the feature.
        if !self.monolithic && self.cfg.gen_placement == GenPlacement::Disaggregated {
            let mut prefill_instances = 0;
            let mut decode_instances = 0;
            for (idx, v) in self.decode_instances.iter().enumerate() {
                if v.is_empty() {
                    continue;
                }
                decode_instances += v.iter().filter(|i| i.up).count();
                prefill_instances += self.instances[idx].iter().filter(|i| i.up).count();
            }
            self.recorder.set_disagg(DisaggStats {
                handoffs: self.handoffs,
                transfer_total: self.transfer_total,
                prefill_instances,
                decode_instances,
                kv_prefix: self.kv_counters.snapshot(),
            });
        }
        let final_instances = self
            .instances
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .map(|(idx, v)| {
                (self.graph.node(NodeId(idx)).name.clone(), v.iter().filter(|i| i.up).count())
            })
            .collect();
        SimResult {
            report: self.recorder.report(),
            controller_decision_secs: if self.decisions > 0 {
                self.decision_time / self.decisions as f64
            } else {
                0.0
            },
            controller_decisions: self.decisions,
            lp_solve_secs: self.plane.autoscaler.solve_times.clone(),
            reallocations: self.plane.autoscaler.commits.len(),
            final_instances,
            residual_bindings: self.plane.router.total_bindings(),
            events: self.q.processed(),
            clamped: self.q.clamped(),
        }
    }

    /// Fan a request out across a fork's branches: one sibling subtask
    /// per branch, each with its own rng stream and a shared join cell.
    fn do_fork(&mut self, req: usize, node: NodeId, parent: u32) {
        let fg = self.fork_map[node.0].clone().expect("fork node");
        for &ei in &fg.edges {
            self.plane.on_edge(ei, node);
        }
        let mut spawned = Vec::with_capacity(fg.targets.len());
        {
            let r = &mut self.reqs[req];
            r.next_cell += 1;
            let cell_id = r.next_cell;
            for &target in &fg.targets {
                r.next_branch += 1;
                let b = r.next_branch;
                let child = r.rng_mut(parent).fork();
                r.branches.push(BranchState { id: b, cell: cell_id, rng: child, cancelled: false });
                spawned.push((b, target));
            }
            r.cells.push((
                cell_id,
                JoinCell {
                    join: fg.join,
                    need: fg.need,
                    parent,
                    outstanding: spawned.iter().map(|&(b, _)| b).collect(),
                    arrivals: Vec::new(),
                },
            ));
        }
        for (b, target) in spawned {
            self.q.schedule_in(
                self.cfg.controller_overhead,
                Ev::Dispatch {
                    req,
                    node: target,
                    branch: b,
                    earliest_finish: 0.0,
                    stream_chunks: 0.0,
                },
            );
        }
    }

    /// One fork branch reached its join barrier. Returns control-flow to
    /// the caller: when the barrier releases, the join node is dispatched
    /// exactly once on the fork's parent branch context; FirstK losers
    /// are cancelled without touching queue or engine state directly.
    fn on_join_arrival(&mut self, req: usize, branch: u32, cell_id: u32, node: NodeId) {
        let now = self.q.now();
        let (released, cell) = {
            let r = &mut self.reqs[req];
            r.purge_branch(branch);
            let cell = r.cell_mut(cell_id).expect("join cell");
            debug_assert_eq!(cell.join, node, "branch arrived at a foreign join");
            cell.outstanding.retain(|&b| b != branch);
            cell.arrivals.push(now);
            if cell.arrivals.len() < cell.need {
                (false, None)
            } else {
                let cell = r.take_cell(cell_id).expect("join cell");
                for &loser in &cell.outstanding {
                    r.cancel_branch(loser);
                }
                (true, Some(cell))
            }
        };
        if !released {
            return;
        }
        let cell = cell.expect("released cell");
        // Join-wait: time the earlier arrivals stalled at the barrier
        // waiting for the release — fork slack the breakdown table
        // surfaces instead of folding into end-to-end latency.
        let stall: f64 =
            cell.arrivals[..cell.arrivals.len() - 1].iter().map(|t| now - t).sum();
        self.recorder.on_join_wait(&self.graph.node(node).name, stall);
        self.dispatch_work(req, node, cell.parent, 0.0, 0.0);
    }

    /// Admission gate for one arrival; true = admitted. The decision is
    /// entirely the plane's — this only collects the queue picture and
    /// books the shed. With admission disabled (the default) no plane
    /// call happens at all, so the pre-admission event stream is
    /// untouched.
    fn admit_arrival(&mut self, req: usize, entry: NodeId, now: f64) -> bool {
        if self.monolithic
            || self.cfg.system != SystemKind::Harmonia
            || !self.plane.admission_enabled()
        {
            return true;
        }
        let t0 = Instant::now();
        let (queued, capacity) = self.node_load(entry);
        let features = self.reqs[req].features;
        let deadline = self.reqs[req].deadline;
        let decision = self.plane.admit(entry, &features, now, deadline, queued, capacity);
        self.decision_time += t0.elapsed().as_secs_f64();
        self.decisions += 1;
        if decision.admitted() {
            return true;
        }
        // Shed: terminal for the request, no latency sample recorded.
        self.reqs[req].done = true;
        self.shed += 1;
        self.recorder.on_shed();
        false
    }

    /// Queued work and concurrent capacity of one component (all
    /// instances + the central queue) — the admission gate's inputs.
    fn node_load(&self, node: NodeId) -> (usize, usize) {
        let central = self.node_queues[node.0].len();
        let v = &self.instances[node.0];
        let mut queued: usize = v.iter().map(|i| i.queue.len()).sum::<usize>() + central;
        let mut capacity: usize = v.iter().filter(|i| i.up).map(|i| i.slots).sum();
        // Split generator: the decode pool's backlog and slots are part
        // of the same logical component — admission must see a saturated
        // decode side even when the prefill pool is idle.
        let d = &self.decode_instances[node.0];
        if !d.is_empty() {
            queued += self.decode_queues[node.0].len();
            capacity += d.iter().filter(|i| i.up).map(|i| i.slots).sum::<usize>();
        }
        (queued, capacity)
    }

    fn first_node(&self) -> NodeId {
        self.graph
            .successors(self.graph.source)
            .next()
            .expect("source has a successor")
            .to
    }

    // ---- event handlers --------------------------------------------------

    fn on_dispatch(
        &mut self,
        req: usize,
        node: NodeId,
        branch: u32,
        earliest_finish: f64,
        stream_chunks: f64,
    ) {
        // Cancelled FirstK loser: dropped before it touches any queue or
        // slot (it was still between stages when the barrier released).
        if self.reqs[req].take_cancelled(branch) {
            return;
        }
        if node == self.graph.sink {
            return self.complete(req);
        }
        if self.monolithic {
            return self.monolith_dispatch(req);
        }
        // A branch arriving at its fork's join barrier reports there
        // instead of executing the join directly.
        if branch != 0 {
            let r = &self.reqs[req];
            if let Some(cell_id) = r.cell_of(branch) {
                if r.cell(cell_id).map(|c| c.join) == Some(node) {
                    return self.on_join_arrival(req, branch, cell_id, node);
                }
            }
        }
        self.dispatch_work(req, node, branch, earliest_finish, stream_chunks);
    }

    /// Route + enqueue/start one unit of work at `node` (the pre-fork
    /// dispatch body, now shared by trunk dispatches, branch subtasks,
    /// and released join barriers).
    fn dispatch_work(
        &mut self,
        req: usize,
        node: NodeId,
        branch: u32,
        earliest_finish: f64,
        stream_chunks: f64,
    ) {
        let now = self.q.now();
        // Controller decision (routing + priority) — timed for Fig. 13.
        // The route snapshot reuses one scratch buffer across every
        // dispatch (`route_states`) instead of allocating per hop.
        let t0 = Instant::now();
        let spec_stateful = self.graph.node(node).stateful;
        let mut states = std::mem::take(&mut self.route_states);
        states.clear();
        states.extend(self.instances[node.0].iter().map(|i| InstanceState {
            active: i.active,
            queued: i.queue.len(),
            slots: i.slots,
            expected_reentries: i.expected_reentries,
            up: i.up,
        }));
        let pick = self.plane.route(req as u64, node, spec_stateful, &states);
        self.route_states = states;
        let slack_key =
            self.plane
                .enqueue_key(node, &self.reqs[req].features, now, self.reqs[req].deadline);
        self.decision_time += t0.elapsed().as_secs_f64();
        self.decisions += 1;

        self.plane.on_enqueue(node);
        let item = QueuedItem { req, branch, enqueued_at: now, earliest_finish, stream_chunks };
        // Disaggregated placement owns the generator's engine model: the
        // routed pick lands in the prefill pool, and the batching-mode
        // branches below never see a split generator.
        if self.disagg_node(node) {
            let inst = &mut self.instances[node.0][pick];
            if inst.up && inst.active < inst.slots {
                inst.active += 1;
                self.start_prefill(req, node, pick, item);
            } else if spec_stateful {
                inst.queue.push(slack_key, item);
            } else {
                self.node_queues[node.0].push(slack_key, item);
            }
            return;
        }
        // Static run-to-completion batching: the generator engine serves
        // one batch at a time, so a request may only start when the
        // instance is idle — and then it drags queued work in with it up
        // to the batch capacity. Mid-batch arrivals wait even when decode
        // slots are nominally free; that head-of-line blocking is exactly
        // what `GenBatching::Continuous` removes.
        if self.gen_mode(node) == GenBatching::Static {
            let idle = {
                let i = &self.instances[node.0][pick];
                i.up && i.active == 0
            };
            if idle {
                let batch = self.fill_static_batch(node, pick, Some(item));
                self.start_static_batch(node, pick, batch);
            } else if spec_stateful {
                self.instances[node.0][pick].queue.push(slack_key, item);
            } else {
                self.node_queues[node.0].push(slack_key, item);
            }
            return;
        }
        let inst = &mut self.instances[node.0][pick];
        if inst.up && inst.active < inst.slots {
            inst.active += 1;
            self.start_service(req, node, pick, item);
        } else if spec_stateful {
            // Must run on the bound instance: wait in its local queue.
            inst.queue.push(slack_key, item);
        } else {
            // Central component queue: any instance of `node` may pull it.
            self.node_queues[node.0].push(slack_key, item);
        }
    }

    /// Generator batching mode in effect for `node` (Legacy for every
    /// non-generator component, whatever the config says).
    fn gen_mode(&self, node: NodeId) -> GenBatching {
        if matches!(self.graph.node(node).kind, ComponentKind::Generator) {
            self.cfg.gen_batching
        } else {
            GenBatching::Legacy
        }
    }

    /// Fill a run-to-completion batch on an idle instance of `node`:
    /// `seed` (the item that triggered formation, if any) plus queued
    /// work — bound (stateful) queue first, then the central component
    /// queue — up to the instance's slot count. Sets the instance's
    /// active count to the batch size.
    fn fill_static_batch(
        &mut self,
        node: NodeId,
        pick: usize,
        seed: Option<QueuedItem>,
    ) -> Vec<QueuedItem> {
        let i = &mut self.instances[node.0][pick];
        let mut batch: Vec<QueuedItem> = seed.into_iter().collect();
        while batch.len() < i.slots {
            match i.queue.pop().or_else(|| self.node_queues[node.0].pop()) {
                // Lazy discard: a queued FirstK loser never enters the
                // batch (its slot was never held, nothing to release).
                Some(it) if self.reqs[it.req].take_cancelled(it.branch) => {
                    self.plane.on_cancelled(node);
                }
                Some(it) => batch.push(it),
                None => break,
            }
        }
        i.active = batch.len();
        batch
    }

    /// Record a request's time-to-first-token once (first generator
    /// visit; later rewrite-loop visits refine an answer that already
    /// streamed its first token).
    fn record_ttft(&mut self, req: usize, at: f64) {
        let r = &mut self.reqs[req];
        if !r.ttft_done {
            r.ttft_done = true;
            let arrival = r.arrival;
            self.recorder.on_first_token((at - arrival).max(0.0));
        }
    }

    /// Start one run-to-completion generator batch (`GenBatching::Static`):
    /// every member decodes for the batch's maximum step count and
    /// finishes when the slowest member does. Per-member telemetry
    /// records the full batch duration — the inflated service attribution
    /// whose downstream effects (LP priors, autoscaler targets, slack
    /// predictions) this mode exists to expose.
    fn start_static_batch(&mut self, node: NodeId, pick: usize, items: Vec<QueuedItem>) {
        debug_assert!(!items.is_empty());
        let now = self.q.now();
        // Copy the per-visit scalars out of the spec instead of cloning
        // the whole `NodeSpec` (name + resource vec) on every batch.
        let (shards, cache_hit_rate, quantized, degrade) = {
            let spec = self.graph.node(node);
            (spec.shards, spec.cache_hit_rate, spec.quantized, spec.degrade)
        };
        let colocated = self.instances[node.0][pick].colocated;
        let model = LatencyModel::for_kind(&self.graph.node(node).kind);
        let dcm = DecodeCostModel::generator();
        let b = items.len();
        let max_steps = items
            .iter()
            .map(|it| self.reqs[it.req].features.gen_len)
            .max()
            .unwrap_or(1);
        // Per-member durations (shared decode count, own noise draw);
        // the batch runs until its slowest member finishes. The same
        // per-visit modifiers `start_service` applies (shard factor,
        // cache-hit draw, degrade ladder, colocation) apply here too, so
        // a generator node carrying those specs behaves consistently —
        // and consumes the same rng draws — across batching modes.
        let mut batch_t = 0.0f64;
        for it in &items {
            let features = self.reqs[it.req].features;
            let noise = model.noise(self.reqs[it.req].rng_mut(it.branch));
            let mut t = dcm.static_batch(&features, max_steps, b) * noise;
            t *= super::cluster::shard_service_factor(shards);
            t *= super::cluster::quantized_service_factor(quantized);
            if self.draw_cache_hit(it.req, it.branch, cache_hit_rate) {
                t *= CACHE_HIT_COST_FRAC;
            }
            if self.plane.degrade_enabled() {
                t *= self.plane.service_factor(degrade);
            }
            if colocated {
                t *= COLOCATION_SLOWDOWN;
            }
            // Streamed-input chunk preemption counts toward busy time,
            // exactly as in `start_service`.
            t += it.stream_chunks * CHUNK_PREEMPT;
            batch_t = batch_t.max(t);
        }
        // First tokens emerge after the longest prefill plus one step —
        // expressed as a fraction of the noise-free batch base and scaled
        // by the realized (noisy, modifier-adjusted) batch duration, the
        // same construction the continuous path uses, so both arms of the
        // static-vs-continuous comparison measure TTFT identically. The
        // fraction is ≤ 1, so the decode span below is never negative.
        let max_prefill = items
            .iter()
            .map(|it| dcm.prefill(self.reqs[it.req].features.prompt_len))
            .fold(0.0, f64::max);
        let first_frac =
            (max_prefill + dcm.step(b)) / (max_prefill + max_steps as f64 * dcm.step(b));
        let first = now + batch_t * first_frac;
        for it in items {
            let features = self.reqs[it.req].features;
            let queue_wait = now - it.enqueued_at;
            self.recorder.on_execution(&self.graph.node(node).name, batch_t, queue_wait);
            self.plane.observe_service(node, &features, batch_t);
            self.record_ttft(it.req, first);
            // Per-output-token pace: completion waits out max_steps even
            // though only gen_len of them are this request's — the
            // co-batching tax a short answer pays next to a long one.
            let decode_span = (now + batch_t - first).max(0.0);
            self.recorder
                .on_token_latency(decode_span / features.gen_len.max(1) as f64);
            let finish = (now + batch_t).max(it.earliest_finish);
            self.q.schedule(
                finish,
                Ev::Finish {
                    req: it.req,
                    node,
                    inst: pick,
                    service: batch_t,
                    branch: it.branch,
                },
            );
        }
    }

    fn start_service(&mut self, req: usize, node: NodeId, pick: usize, item: QueuedItem) {
        let now = self.q.now();
        let branch = item.branch;
        let (shards, cache_hit_rate, quantized, degrade, streamable) = {
            let spec = self.graph.node(node);
            (spec.shards, spec.cache_hit_rate, spec.quantized, spec.degrade, spec.streamable)
        };
        let (colocated, active) = {
            let i = &self.instances[node.0][pick];
            (i.colocated, i.active)
        };
        let model = LatencyModel::for_kind(&self.graph.node(node).kind);
        let features = self.reqs[req].features;
        let continuous = self.gen_mode(node) == GenBatching::Continuous;
        // Continuous batching: iteration-level pricing — the request pays
        // prefill plus its *own* decode steps at the occupancy-aware step
        // cost (`active` counts co-resident requests, this one included).
        // The occupancy term replaces `concurrency_slowdown` for stepped
        // generators; exactly one noise draw either way keeps the
        // per-request rng stream aligned with the legacy model (fork
        // subtasks draw from their own branch stream).
        let (mut t, first_frac) = if continuous {
            let dcm = DecodeCostModel::generator();
            let base = dcm.continuous(&features, active);
            let first = dcm.prefill(features.prompt_len) + dcm.step(active);
            let noise = model.noise(self.reqs[req].rng_mut(branch));
            (base * noise, first / base)
        } else {
            (model.sample(&features, self.reqs[req].rng_mut(branch)), 0.0)
        };
        // Sharded components scatter-gather across parallel partitions.
        t *= super::cluster::shard_service_factor(shards);
        // SQ8-quantized index scans run at the calibrated fraction of the
        // f32 scan (factor exactly 1.0 when unquantized — the default).
        t *= super::cluster::quantized_service_factor(quantized);
        // Modeled request cache: a `cache_hit_rate` fraction of visits is
        // served from the memoized embed→retrieve prefix at the hit cost.
        // Per-request sampling (not the mean factor) keeps the latency
        // distribution bimodal — the p50 collapse at high hit rates.
        if self.draw_cache_hit(req, branch, cache_hit_rate) {
            t *= CACHE_HIT_COST_FRAC;
        }
        // Overload degradation: visits to annotated components shrink
        // under the plane's ladder (top-k shrink / hop skip). No rng is
        // consumed and the factor is exactly 1.0 when the policy is off,
        // so default traces replay bit-identically.
        if self.plane.degrade_enabled() {
            t *= self.plane.service_factor(degrade);
        }
        if !continuous {
            t *= concurrency_slowdown(active);
        }
        if colocated {
            t *= COLOCATION_SLOWDOWN;
        }
        // Streamed input: each chunk arrival preempts this instance
        // (§2.2 / Fig. 5) — fine granularity inflates busy time.
        t += item.stream_chunks * CHUNK_PREEMPT;
        let queue_wait = now - item.enqueued_at;
        self.recorder.on_execution(&self.graph.node(node).name, t, queue_wait);
        self.plane.observe_service(node, &features, t);
        if continuous {
            // TTFT = queueing already elapsed + prefill + the first step;
            // per-token pace = the remaining decode span over own tokens.
            let first = t * first_frac;
            self.record_ttft(req, now + first);
            self.recorder
                .on_token_latency(((t - first) / features.gen_len.max(1) as f64).max(0.0));
        }

        let finish = (now + t).max(item.earliest_finish);
        self.q.schedule(finish, Ev::Finish { req, node, inst: pick, service: t, branch });

        // Streaming: pre-route the downstream hop at first-chunk time.
        // Fork nodes never pre-route (all branches dispatch at Finish),
        // and nothing streams INTO a join barrier — the join needs every
        // branch's complete output before it can start.
        if streamable && self.fork_map[node.0].is_none() {
            let (next_node, _) = self.sample_next(req, branch, node);
            if next_node != self.graph.sink && self.graph.node(next_node).join.is_none() {
                let util = self.utilization(next_node);
                let frac = self
                    .stream_policy
                    .effective_fraction(self.effective_stream_mode(), util);
                if frac < 1.0 {
                    let n_chunks = (1.0 / frac).ceil();
                    let floor = finish + CHUNK_OVERHEAD * n_chunks;
                    self.q.schedule(
                        now + frac * t + self.cfg.controller_overhead,
                        Ev::Dispatch {
                            req,
                            node: next_node,
                            branch,
                            earliest_finish: floor,
                            stream_chunks: n_chunks,
                        },
                    );
                    self.reqs[req].pending_stream.push(node);
                    return;
                }
            }
            self.reqs[req].pre_sampled.push((node, next_node));
        }
    }

    // ---- disaggregated generator (prefill → KV handoff → decode) -----------

    /// Modeled KV prefix cache draw: `kv_prefix_hit_rate` is the expected
    /// longest-prefix hit probability over the workload's retrieved-context
    /// segment chains (`cache::kv_prefix` is the live twin; here the DES
    /// prices it statistically, like `draw_cache_hit` prices the query
    /// cache). A zero rate consumes no randomness. Misses count an
    /// insertion too — every missed chain is written back.
    fn draw_kv_prefix_hit(&mut self, req: usize, branch: u32) -> bool {
        let rate = self.cfg.kv_prefix_hit_rate;
        if rate <= 0.0 {
            return false;
        }
        let hit = self.reqs[req].rng_mut(branch).chance(rate);
        if hit {
            self.kv_counters.on_exact_hit();
        } else {
            self.kv_counters.on_miss();
            self.kv_counters.on_insertion();
        }
        hit
    }

    /// Disaggregated generator, phase one: price the visit with the
    /// continuous-batching anatomy (one noise draw, occupancy-aware step
    /// cost, the same modifier order as `start_service`), split it into
    /// the request's prefill and decode spans, apply the modeled KV
    /// prefix cache to the prefill side, and schedule prefill completion.
    /// The decode span and transfer cost ride along in the event — decode
    /// capacity is committed only when the handoff lands. Managed
    /// streaming out of a split generator is not modeled: the first-token
    /// path is already pinned by the handoff chain.
    fn start_prefill(&mut self, req: usize, node: NodeId, pick: usize, item: QueuedItem) {
        let now = self.q.now();
        let branch = item.branch;
        let (shards, cache_hit_rate, quantized, degrade) = {
            let spec = self.graph.node(node);
            (spec.shards, spec.cache_hit_rate, spec.quantized, spec.degrade)
        };
        let (colocated, active) = {
            let i = &self.instances[node.0][pick];
            (i.colocated, i.active)
        };
        let model = LatencyModel::for_kind(&self.graph.node(node).kind);
        let features = self.reqs[req].features;
        let dcm = DecodeCostModel::generator();
        let base = dcm.continuous(&features, active);
        let noise = model.noise(self.reqs[req].rng_mut(branch));
        let mut t = base * noise;
        t *= super::cluster::shard_service_factor(shards);
        t *= super::cluster::quantized_service_factor(quantized);
        if self.draw_cache_hit(req, branch, cache_hit_rate) {
            t *= CACHE_HIT_COST_FRAC;
        }
        if self.plane.degrade_enabled() {
            t *= self.plane.service_factor(degrade);
        }
        if colocated {
            t *= COLOCATION_SLOWDOWN;
        }
        t += item.stream_chunks * CHUNK_PREEMPT;
        // Exact split: prefill share from the noise-free anatomy, decode
        // is the remainder — the two spans always sum to the full sample,
        // so placement moves time between pools without changing a
        // visit's pre-transfer cost.
        let pf = (dcm.prefill(features.prompt_len) / base.max(1e-12)).clamp(0.0, 1.0);
        let mut prefill = t * pf;
        let decode = t - prefill;
        // A prefix-cache hit restores the shared context prefix and
        // re-runs only the tail of prefill (per-request draw keeps the
        // TTFT distribution bimodal, like the query cache's p50 story).
        if self.draw_kv_prefix_hit(req, branch) {
            prefill *= KV_PREFIX_HIT_COST_FRAC;
        }
        let transfer = self.cfg.kv_transfer.cost(features.prompt_len);
        let total = prefill + transfer + decode;
        let queue_wait = now - item.enqueued_at;
        self.recorder.on_execution(&self.prefill_names[node.0], prefill, queue_wait);
        self.plane.observe_service(node, &features, total);
        self.q.schedule(
            now + prefill,
            Ev::PrefillFinish {
                req,
                node,
                inst: pick,
                branch,
                decode,
                transfer,
                total,
                earliest_finish: item.earliest_finish,
            },
        );
    }

    /// Phase two: the prefill pool frees its slot (pulling queued prefill
    /// work in — the same bound-first, lazily-discarding pull as
    /// `on_finish`), and the request's KV pages go on the wire. A
    /// cancelled FirstK loser still rides the full handoff chain, exactly
    /// as a cancelled collocated request runs its service to completion.
    #[allow(clippy::too_many_arguments)]
    fn on_prefill_finish(
        &mut self,
        req: usize,
        node: NodeId,
        inst: usize,
        branch: u32,
        decode: f64,
        transfer: f64,
        total: f64,
        earliest_finish: f64,
    ) {
        let next_item = {
            let i = &mut self.instances[node.0][inst];
            i.active = i.active.saturating_sub(1);
            if i.up && i.active < i.slots {
                loop {
                    match i.queue.pop().or_else(|| self.node_queues[node.0].pop()) {
                        Some(it) if self.reqs[it.req].take_cancelled(it.branch) => {
                            self.plane.on_cancelled(node);
                        }
                        other => break other,
                    }
                }
            } else {
                None
            }
        };
        if let Some(item) = next_item {
            self.instances[node.0][inst].active += 1;
            let r = item.req;
            self.start_prefill(r, node, inst, item);
        }
        self.handoffs += 1;
        self.transfer_total += transfer;
        self.q.schedule_in(
            transfer,
            Ev::KvHandoff { req, node, branch, decode, total, earliest_finish },
        );
    }

    /// Phase three: the KV transfer landed; admit to the decode pool.
    /// Decode admission is an engine decision, not a routed controller
    /// decision: deterministic least-loaded pick, lowest index on ties.
    fn on_kv_handoff(
        &mut self,
        req: usize,
        node: NodeId,
        branch: u32,
        decode: f64,
        total: f64,
        earliest_finish: f64,
    ) {
        let now = self.q.now();
        let item = DecodeItem { req, branch, decode, total, enqueued_at: now, earliest_finish };
        let pick = self.decode_instances[node.0]
            .iter()
            .enumerate()
            .filter(|(_, i)| i.up && i.active < i.slots)
            .min_by_key(|&(idx, i)| (i.active, idx))
            .map(|(idx, _)| idx);
        match pick {
            Some(p) => {
                self.decode_instances[node.0][p].active += 1;
                self.start_decode(node, p, item);
            }
            None => {
                self.decode_queues[node.0].push(now, item);
            }
        }
    }

    /// Phase four: the decode pool serves the request's own decode span.
    /// The first token emerges one step into the span — TTFT under
    /// disaggregation includes prefill, transfer, and decode-pool
    /// queueing, which is exactly the tradeoff the placement sweep
    /// measures.
    fn start_decode(&mut self, node: NodeId, pick: usize, item: DecodeItem) {
        let now = self.q.now();
        let features = self.reqs[item.req].features;
        self.recorder
            .on_execution(&self.decode_names[node.0], item.decode, now - item.enqueued_at);
        let steps = features.gen_len.max(1) as f64;
        self.record_ttft(item.req, now + item.decode / steps);
        self.recorder.on_token_latency(item.decode / steps);
        let finish = (now + item.decode).max(item.earliest_finish);
        self.q.schedule(
            finish,
            Ev::DecodeFinish {
                req: item.req,
                node,
                inst: pick,
                branch: item.branch,
                total: item.total,
            },
        );
    }

    /// Phase five: last token out. The plane sees the generator as one
    /// logical component — a single `on_complete` with the combined
    /// prefill + transfer + decode attribution, paired with the single
    /// `on_enqueue` at dispatch.
    fn on_decode_finish(&mut self, req: usize, node: NodeId, inst: usize, branch: u32, total: f64) {
        self.plane.on_complete(node, total);
        let next_item = {
            let i = &mut self.decode_instances[node.0][inst];
            i.active = i.active.saturating_sub(1);
            if i.up && i.active < i.slots {
                self.decode_queues[node.0].pop()
            } else {
                None
            }
        };
        if let Some(item) = next_item {
            self.decode_instances[node.0][inst].active += 1;
            self.start_decode(node, inst, item);
        }
        // Cancelled mid-flight (FirstK loser): the visit ends here. No
        // streamed pre-dispatch exists out of a split generator, so the
        // mark is always consumable at this point.
        if self.reqs[req].take_cancelled(branch) {
            return;
        }
        if self.fork_map[node.0].is_some() {
            return self.do_fork(req, node, branch);
        }
        let next = self.sample_next(req, branch, node).0;
        self.q.schedule_in(
            self.cfg.controller_overhead,
            Ev::Dispatch { req, node: next, branch, earliest_finish: 0.0, stream_chunks: 0.0 },
        );
    }

    fn on_finish(&mut self, req: usize, node: NodeId, inst: usize, service: f64, branch: u32) {
        if self.monolithic {
            return self.monolith_finish(req, inst);
        }
        self.plane.on_complete(node, service);
        if self.gen_mode(node) == GenBatching::Static {
            // Run-to-completion: the engine frees only when the whole
            // batch has finished; the last member out pulls the next
            // batch in.
            let idle = {
                let i = &mut self.instances[node.0][inst];
                i.active = i.active.saturating_sub(1);
                i.up && i.active == 0
            };
            if idle {
                let batch = self.fill_static_batch(node, inst, None);
                if !batch.is_empty() {
                    self.start_static_batch(node, inst, batch);
                }
            }
        } else {
            // Free the slot; pull next queued item: bound (stateful) work
            // first, then the central component queue. Cancelled FirstK
            // losers are discarded on pop — they hold no slot.
            let next_item = {
                let i = &mut self.instances[node.0][inst];
                i.active = i.active.saturating_sub(1);
                if i.up && i.active < i.slots {
                    loop {
                        match i.queue.pop().or_else(|| self.node_queues[node.0].pop()) {
                            Some(it) if self.reqs[it.req].take_cancelled(it.branch) => {
                                self.plane.on_cancelled(node);
                            }
                            other => break other,
                        }
                    }
                } else {
                    None
                }
            };
            if let Some(item) = next_item {
                self.instances[node.0][inst].active += 1;
                let r = item.req;
                self.start_service(r, node, inst, item);
            }
        }
        // Cancelled mid-service: the slot was freed above; the subtask
        // ends here — no onward dispatch, no queue corruption. If this
        // stage already streamed a downstream dispatch, the cancellation
        // mark must survive until that in-flight event fires and is
        // dropped (consuming it here would revive the branch as a
        // zombie when the streamed hop lands).
        if self.reqs[req].is_cancelled(branch) {
            let r = &mut self.reqs[req];
            let streamed = r.remove_pending_stream(node);
            r.remove_pre_sampled(node);
            if !streamed {
                r.take_cancelled(branch);
            }
            return;
        }
        // If streaming already dispatched this hop, we're done here.
        if self.reqs[req].remove_pending_stream(node) {
            return;
        }
        // Parallel fan-out happens at Finish: every branch dispatches.
        if self.fork_map[node.0].is_some() {
            return self.do_fork(req, node, branch);
        }
        let next = match self.reqs[req].remove_pre_sampled(node) {
            Some(n) => n,
            None => self.sample_next(req, branch, node).0,
        };
        self.q.schedule_in(
            self.cfg.controller_overhead,
            Ev::Dispatch { req, node: next, branch, earliest_finish: 0.0, stream_chunks: 0.0 },
        );
    }

    /// Sample the actual outgoing branch from the spec probabilities (the
    /// ground-truth workload), recording edge telemetry. Fork nodes never
    /// sample — [`SimWorld::do_fork`] dispatches every branch.
    fn sample_next(&mut self, req: usize, branch: u32, node: NodeId) -> (NodeId, bool) {
        let out = self.adj.out_edges(node);
        debug_assert!(!out.is_empty(), "work node must have successors");
        // Inlined weighted draw over the adjacency slice — same arithmetic
        // as [`Rng::weighted`] (one `f64()` draw, cumulative subtraction,
        // last index on underflow) but with zero per-hop allocation.
        let total: f64 = out.iter().map(|&i| self.graph.edges[i].prob()).sum();
        let mut x = self.reqs[req].rng_mut(branch).f64() * total;
        let mut pick = out.len() - 1;
        for (k, &i) in out.iter().enumerate() {
            x -= self.graph.edges[i].prob();
            if x <= 0.0 {
                pick = k;
                break;
            }
        }
        let picked = &self.graph.edges[out[pick]];
        let (mut idx, mut to, mut back) = (out[pick], picked.to, picked.back_edge);
        // Degrade ladder, iteration capping: at severe overload a
        // CapIterations component (critic-style loop gate) takes its exit
        // branch — the edge toward the sink, else its best forward edge —
        // instead of re-entering the refinement loop. The rng draw above
        // always happens, so enabling the policy shifts no other
        // request's random stream.
        if self.plane.degrade_enabled()
            && self.plane.cap_iterations(self.graph.node(node).degrade)
        {
            let exit = out
                .iter()
                .map(|&i| (i, &self.graph.edges[i]))
                .find(|(_, e)| e.to == self.graph.sink)
                .or_else(|| {
                    out.iter()
                        .map(|&i| (i, &self.graph.edges[i]))
                        .filter(|(_, e)| !e.back_edge)
                        .max_by(|a, b| a.1.prob().total_cmp(&b.1.prob()))
                });
            if let Some((eidx, e)) = exit {
                if eidx != idx {
                    let (eto, eback) = (e.to, e.back_edge);
                    self.plane.counters.on_degraded();
                    idx = eidx;
                    to = eto;
                    back = eback;
                }
            }
        }
        self.plane.on_edge(idx, node);
        (to, back)
    }

    fn complete(&mut self, req: usize) {
        let now = self.q.now();
        let r = &mut self.reqs[req];
        if r.done {
            return;
        }
        r.done = true;
        self.completed += 1;
        self.recorder.on_completion(r.arrival, now, r.deadline);
        self.plane.release(req as u64);
    }

    /// Draw whether this visit is served by the modeled request cache
    /// (`NodeSpec::cache_hit_rate`); uncached nodes consume no
    /// randomness, so pre-cache traces replay bit-identically.
    fn draw_cache_hit(&mut self, req: usize, branch: u32, hit_rate: f64) -> bool {
        if hit_rate <= 0.0 {
            return false;
        }
        let hit = self.reqs[req].rng_mut(branch).chance(hit_rate);
        if hit {
            self.cache_counters.on_exact_hit();
        } else {
            self.cache_counters.on_miss();
        }
        hit
    }

    fn utilization(&self, node: NodeId) -> f64 {
        let v = &self.instances[node.0];
        // A node that was never provisioned reads as unloaded — the same
        // answer the old map gave for a missing key.
        if v.is_empty() {
            return 0.0;
        }
        let mut cap: usize = v.iter().filter(|i| i.up).map(|i| i.slots).sum();
        let queued_central = self.node_queues[node.0].len();
        let mut load: usize =
            v.iter().map(|i| i.active + i.queue.len()).sum::<usize>() + queued_central;
        let d = &self.decode_instances[node.0];
        if !d.is_empty() {
            cap += d.iter().filter(|i| i.up).map(|i| i.slots).sum::<usize>();
            load += d.iter().map(|i| i.active).sum::<usize>() + self.decode_queues[node.0].len();
        }
        if cap == 0 {
            return 1.0;
        }
        load as f64 / cap as f64
    }

    fn effective_stream_mode(&self) -> StreamingMode {
        match self.cfg.system {
            SystemKind::Harmonia if self.cfg.ablation.stream_mgmt => StreamingMode::Managed,
            _ => self.cfg.streaming,
        }
    }

    // ---- monolithic (LangChain) execution ---------------------------------

    fn monolith_dispatch(&mut self, req: usize) {
        let now = self.q.now();
        let t0 = Instant::now();
        let mut states = std::mem::take(&mut self.route_states);
        states.clear();
        states.extend(self.instances[self.graph.source.0].iter().map(|i| InstanceState {
            active: i.active,
            queued: i.queue.len(),
            slots: i.slots,
            expected_reentries: 0.0,
            up: i.up,
        }));
        let pick = self.plane.route(req as u64, self.graph.source, false, &states);
        self.route_states = states;
        self.decision_time += t0.elapsed().as_secs_f64();
        self.decisions += 1;
        let item = QueuedItem {
            req,
            branch: 0,
            enqueued_at: now,
            earliest_finish: 0.0,
            stream_chunks: 0.0,
        };
        let inst = &mut self.instances[self.graph.source.0][pick];
        if inst.active < inst.slots {
            inst.active += 1;
            self.monolith_start(req, pick, item);
        } else {
            inst.queue.push(0.0, item);
        }
    }

    fn monolith_start(&mut self, req: usize, pick: usize, item: QueuedItem) {
        let now = self.q.now();
        let active = self.instances[self.graph.source.0][pick].active;
        // Walk the whole pipeline inside the replica, summing stage times
        // (function calls: no cross-component overhead, no overlap —
        // fork branches SERIALIZE here, which is exactly the contrast
        // the parallel-dataflow bench draws against the monolith).
        let mut hops = 0usize;
        let mut first_wait = Some(now - item.enqueued_at);
        let total = if let Some(fg) = self.fork_map[self.graph.source.0].clone() {
            let mut t = 0.0;
            for &entry in &fg.targets {
                t += self
                    .monolith_chain(req, entry, Some(fg.join), active, &mut hops, &mut first_wait);
            }
            t + self.monolith_chain(req, fg.join, None, active, &mut hops, &mut first_wait)
        } else {
            let entry = self.first_node();
            self.monolith_chain(req, entry, None, active, &mut hops, &mut first_wait)
        };
        self.q.schedule(
            now + total,
            Ev::Finish { req, node: self.graph.source, inst: pick, service: total, branch: 0 },
        );
    }

    /// Serial stage walk from `cur` until the sink or `stop` (a fork's
    /// join, exclusive); fork nodes recurse over their branches in
    /// declaration order, then resume at the join. Trunk rng throughout —
    /// a monolithic replica is one call stack.
    fn monolith_chain(
        &mut self,
        req: usize,
        mut cur: NodeId,
        stop: Option<NodeId>,
        active: usize,
        hops: &mut usize,
        first_wait: &mut Option<f64>,
    ) -> f64 {
        let features = self.reqs[req].features;
        let mut total = 0.0;
        while cur != self.graph.sink && Some(cur) != stop && *hops < 1000 {
            *hops += 1;
            let (shards, cache_hit_rate, quantized) = {
                let spec = self.graph.node(cur);
                (spec.shards, spec.cache_hit_rate, spec.quantized)
            };
            let model = LatencyModel::for_kind(&self.graph.node(cur).kind);
            let mut t = model.sample(&features, self.reqs[req].rng_mut(0));
            t *= super::cluster::shard_service_factor(shards);
            t *= super::cluster::quantized_service_factor(quantized);
            if self.draw_cache_hit(req, 0, cache_hit_rate) {
                t *= CACHE_HIT_COST_FRAC;
            }
            t *= concurrency_slowdown(active);
            total += t;
            let wait = first_wait.take().unwrap_or(0.0);
            self.recorder.on_execution(&self.graph.node(cur).name, t, wait);
            if let Some(fg) = self.fork_map[cur.0].clone() {
                for &ei in &fg.edges {
                    self.plane.on_edge(ei, cur);
                }
                for &entry in &fg.targets {
                    total +=
                        self.monolith_chain(req, entry, Some(fg.join), active, hops, first_wait);
                }
                cur = fg.join;
            } else {
                cur = self.sample_next(req, 0, cur).0;
            }
        }
        total
    }

    fn monolith_finish(&mut self, req: usize, inst: usize) {
        self.complete(req);
        let next_item = {
            let i = &mut self.instances[self.graph.source.0][inst];
            i.active = i.active.saturating_sub(1);
            i.queue.pop()
        };
        if let Some(item) = next_item {
            self.instances[self.graph.source.0][inst].active += 1;
            let r = item.req;
            self.monolith_start(r, inst, item);
        }
    }

    // ---- control loop ------------------------------------------------------

    fn on_control_tick(&mut self) {
        let now = self.q.now();
        if self.monolithic || self.cfg.system != SystemKind::Harmonia {
            return;
        }
        // Refresh expected re-entries for state-aware routing.
        for idx in 0..self.instances.len() {
            if self.instances[idx].is_empty() {
                continue;
            }
            let bound = self.plane.router.bindings_for(NodeId(idx)) as f64;
            let v = &mut self.instances[idx];
            let n = v.len().max(1) as f64;
            for i in v.iter_mut() {
                i.expected_reentries = bound / n;
            }
        }
        // The unified tick: overload ladder → rekey → autoscale.
        let budgets = Cluster::paper_testbed().budgets();
        let util = self.global_utilization();
        let outcome = if self.cfg.ablation.realloc {
            self.plane
                .tick(now, util, Some((&self.graph, &self.prior, &budgets)))
        } else {
            self.plane.tick(now, util, None)
        };
        if outcome.rekey {
            self.rekey_queues(now);
        }
        if let Some(plan) = outcome.plan {
            self.apply_plan(plan);
        }
    }

    /// Cluster-wide (queued + active) work per concurrent slot — the
    /// overload ladder's input signal.
    fn global_utilization(&self) -> f64 {
        let mut load = 0usize;
        let mut cap = 0usize;
        for (idx, v) in self.instances.iter().enumerate() {
            load += v.iter().map(|i| i.active + i.queue.len()).sum::<usize>();
            load += self.node_queues[idx].len();
            cap += v.iter().filter(|i| i.up).map(|i| i.slots).sum::<usize>();
        }
        for (idx, v) in self.decode_instances.iter().enumerate() {
            load += v.iter().map(|i| i.active).sum::<usize>();
            load += self.decode_queues[idx].len();
            cap += v.iter().filter(|i| i.up).map(|i| i.slots).sum::<usize>();
        }
        if cap == 0 {
            return 0.0;
        }
        load as f64 / cap as f64
    }

    /// Rebuild every LeastSlack queue under fresh slack keys (slack
    /// decays with the clock; the plane's tick asked for this). The key
    /// function is the plane's — this is mechanical application only.
    fn rekey_queues(&mut self, now: f64) {
        let reqs = &self.reqs;
        let plane = &self.plane;
        for (idx, q) in self.node_queues.iter_mut().enumerate() {
            let node = NodeId(idx);
            q.rekey(|item| {
                let r = &reqs[item.req];
                plane.slack_value(node, &r.features, now, r.deadline)
            });
        }
        for (idx, v) in self.instances.iter_mut().enumerate() {
            let node = NodeId(idx);
            for inst in v.iter_mut() {
                inst.queue.rekey(|item| {
                    let r = &reqs[item.req];
                    plane.slack_value(node, &r.features, now, r.deadline)
                });
            }
        }
    }

    fn apply_plan(&mut self, plan: HashMap<NodeId, usize>) {
        let now = self.q.now();
        let cold = self.cfg.cold_start;
        for (node, target) in plan {
            // The autoscaler's targets are placement-blind (one pool per
            // node); resizing a split generator from them would corrupt
            // the LP-chosen prefill/decode balance. Pool sizes are fixed
            // at provisioning for this run.
            if self.disagg_node(node) {
                continue;
            }
            let have = self.instances[node.0].len();
            if target > have {
                for _ in have..target {
                    let mut inst = self.make_instance(node);
                    inst.up = false; // cold start
                    self.instances[node.0].push(inst);
                    let idx = self.instances[node.0].len() - 1;
                    self.q.schedule(now + cold, Ev::InstanceUp { node, inst: idx });
                }
            } else if target < have {
                // `have`/`target` count deployable units; base_instances is
                // a per-replica floor, so convert for sharded nodes (one
                // unit = `shards` replicas).
                let spec = self.graph.node(node);
                let floor = if spec.shards > 1 {
                    spec.base_instances.div_ceil(spec.shards).max(1)
                } else {
                    spec.base_instances.max(1)
                };
                let keep = target.max(floor);
                // Slot-leak fix (audit): a drained instance never pulls
                // from its local queue again, so stateful-bound items
                // parked there would starve forever. Displace them into
                // the central component queue under fresh slack keys —
                // statefulness is a routing preference in the sim, and a
                // re-route beats a request that never completes.
                let mut displaced: Vec<QueuedItem> = Vec::new();
                for i in self.instances[node.0].iter_mut().skip(keep) {
                    i.up = false;
                    while let Some(it) = i.queue.pop() {
                        displaced.push(it);
                    }
                }
                for it in displaced {
                    let r = &self.reqs[it.req];
                    let key = self.plane.slack_value(node, &r.features, now, r.deadline);
                    self.node_queues[node.0].push(key, it);
                }
            }
        }
    }

    fn on_instance_up(&mut self, node: NodeId, inst: usize) {
        let popped = {
            if inst >= self.instances[node.0].len() {
                return;
            }
            let i = &mut self.instances[node.0][inst];
            i.up = true;
            let mut items = Vec::new();
            while i.active + items.len() < i.slots {
                match i.queue.pop().or_else(|| self.node_queues[node.0].pop()) {
                    Some(it) if self.reqs[it.req].take_cancelled(it.branch) => {
                        self.plane.on_cancelled(node);
                    }
                    Some(it) => items.push(it),
                    None => break,
                }
            }
            i.active += items.len();
            items
        };
        if popped.is_empty() {
            return;
        }
        if self.disagg_node(node) {
            // Defensive: `apply_plan` never resizes a split generator, so
            // this only fires if that invariant changes — prefill work
            // must then start on the prefill path.
            for item in popped {
                let r = item.req;
                self.start_prefill(r, node, inst, item);
            }
        } else if self.gen_mode(node) == GenBatching::Static {
            // A cold-started static-batching engine starts its backlog as
            // one run-to-completion batch, not as independent slots.
            self.start_static_batch(node, inst, popped);
        } else {
            for item in popped {
                let r = item.req;
                self.start_service(r, node, inst, item);
            }
        }
    }
}

impl SimWorld {
    /// Convenience runner.
    pub fn simulate(graph: PipelineGraph, cfg: SimConfig) -> SimResult {
        SimWorld::new(graph, cfg).run()
    }
}

/// Sweep helper: run one (system, rate) point with a standard trace.
pub fn run_point(
    system: SystemKind,
    graph: PipelineGraph,
    rate: f64,
    n: usize,
    slo: Option<f64>,
    seed: u64,
) -> SimResult {
    let trace = TraceConfig { rate, n, slo, ..TraceConfig::default() };
    SimWorld::simulate(graph, SimConfig::new(system, trace, seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;

    fn quick(system: SystemKind, app: &str, rate: f64, n: usize) -> SimResult {
        run_point(system, apps::by_name(app).unwrap(), rate, n, Some(2.0), 42)
    }

    #[test]
    fn all_systems_complete_all_requests() {
        for system in [SystemKind::Harmonia, SystemKind::LangChain, SystemKind::Haystack] {
            let r = quick(system, "v-rag", 8.0, 200);
            assert_eq!(r.report.completed, 200, "{}", system.name());
            assert!(r.report.throughput > 0.0);
            assert!(r.report.mean_latency > 0.0);
        }
    }

    #[test]
    fn recursive_apps_terminate() {
        for app in ["c-rag", "s-rag", "a-rag"] {
            let r = quick(SystemKind::Harmonia, app, 8.0, 150);
            assert_eq!(r.report.completed, 150, "{app}");
        }
    }

    #[test]
    fn latency_grows_with_load() {
        let lo = quick(SystemKind::Harmonia, "c-rag", 4.0, 300);
        let hi = quick(SystemKind::Harmonia, "c-rag", 320.0, 2000);
        assert!(
            hi.report.mean_latency > lo.report.mean_latency,
            "lo {} hi {}",
            lo.report.mean_latency,
            hi.report.mean_latency
        );
    }

    #[test]
    fn harmonia_beats_baselines_on_complex_pipeline_at_load() {
        // The headline claim (Fig. 9) at one operating point.
        let rate = 48.0;
        let n = 600;
        let h = run_point(SystemKind::Harmonia, apps::corrective_rag(), rate, n, None, 7);
        let l = run_point(SystemKind::LangChain, apps::corrective_rag(), rate, n, None, 7);
        let y = run_point(SystemKind::Haystack, apps::corrective_rag(), rate, n, None, 7);
        assert!(
            h.report.throughput > l.report.throughput,
            "harmonia {} vs langchain {}",
            h.report.throughput,
            l.report.throughput
        );
        assert!(
            h.report.throughput > y.report.throughput,
            "harmonia {} vs haystack {}",
            h.report.throughput,
            y.report.throughput
        );
    }

    #[test]
    fn deterministic_for_seed() {
        let a = quick(SystemKind::Harmonia, "s-rag", 16.0, 100);
        let b = quick(SystemKind::Harmonia, "s-rag", 16.0, 100);
        assert_eq!(a.report.completed, b.report.completed);
        assert!((a.report.mean_latency - b.report.mean_latency).abs() < 1e-12);
        assert!((a.report.throughput - b.report.throughput).abs() < 1e-12);
    }

    #[test]
    fn controller_decision_stays_fast() {
        // Fig. 13: decision code must stay well under 2.3 ms/request.
        let r = quick(SystemKind::Harmonia, "a-rag", 32.0, 400);
        assert!(r.controller_decisions > 0);
        assert!(
            r.controller_decision_secs < 2.3e-3,
            "decision {}s",
            r.controller_decision_secs
        );
    }

    #[test]
    fn harmonia_reallocates_under_biased_priors() {
        let trace = TraceConfig { rate: 24.0, n: 2000, slo: None, ..TraceConfig::default() };
        let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, 3);
        cfg.profile_bias = 2.0;
        let r = SimWorld::simulate(apps::corrective_rag(), cfg);
        assert!(r.reallocations > 0, "autoscaler should commit at least once");
        assert!(!r.lp_solve_secs.is_empty());
    }

    #[test]
    fn slo_violations_bounded() {
        let r = quick(SystemKind::Harmonia, "v-rag", 4.0, 200);
        assert!(r.report.slo_violation_rate <= 1.0);
        // At this light load with SLO=2 s the violation rate must be low.
        assert!(
            r.report.slo_violation_rate < 0.2,
            "rate {}",
            r.report.slo_violation_rate
        );
    }

    #[test]
    fn sharded_retrieval_cuts_retriever_service_time() {
        // Same workload, same seed: the 4-shard retriever's mean service
        // time must track the calibrated scatter-gather factor, and the
        // run must still complete end to end.
        let unsharded = run_point(SystemKind::Harmonia, apps::vanilla_rag(), 8.0, 300, Some(2.0), 11);
        let sharded =
            run_point(SystemKind::Harmonia, apps::sharded_vanilla_rag(4), 8.0, 300, Some(2.0), 11);
        assert_eq!(sharded.report.completed, 300);
        let m_full = unsharded.report.components["retriever"].mean_service();
        let m_shard = sharded.report.components["retriever"].mean_service();
        let factor = crate::sim::cluster::shard_service_factor(4);
        assert!(
            m_shard < m_full * (factor + 0.15),
            "sharded mean {m_shard} vs unsharded {m_full} (factor {factor})"
        );
        assert!(m_shard < m_full, "sharding must reduce retrieval service time");
    }

    #[test]
    fn cached_retrieval_cuts_p50_and_reports_hit_rate() {
        // Same workload, same seed: the cached retriever must report a
        // hit rate near the spec's expectation and cut its mean service
        // time toward the closed-form cache factor; uncached runs carry
        // no cache section at all.
        let plain = run_point(SystemKind::Harmonia, apps::vanilla_rag(), 8.0, 400, Some(2.0), 21);
        assert!(plain.report.cache.is_none(), "uncached run must not report a cache");
        let g = apps::cached_vanilla_rag(1.3, 0.8, 2048, 4096);
        let h = g.node_by_name("retriever").unwrap().cache_hit_rate;
        assert!(h >= 0.5, "workload should be hot enough for the p50 claim, got {h}");
        let cached = run_point(SystemKind::Harmonia, g, 8.0, 400, Some(2.0), 21);
        assert_eq!(cached.report.completed, 400);
        let snap = cached.report.cache.expect("cached run reports counters");
        assert!(snap.lookups() >= 400);
        assert!(
            (snap.hit_rate() - h).abs() < 0.1,
            "observed hit rate {} vs modeled {h}",
            snap.hit_rate()
        );
        let m_plain = plain.report.components["retriever"].mean_service();
        let m_cached = cached.report.components["retriever"].mean_service();
        let factor = crate::profile::models::cache_service_factor(h);
        assert!(
            m_cached < m_plain * (factor + 0.15),
            "cached mean {m_cached} vs plain {m_plain} (factor {factor})"
        );
        // End-to-end median improves too: at h ≥ 0.5 the median request
        // hits and skips the full retrieval pass.
        assert!(
            cached.report.p50 < plain.report.p50,
            "cached p50 {} vs plain {}",
            cached.report.p50,
            plain.report.p50
        );
    }

    fn gen_cfg(mode: crate::profile::models::GenBatching, rate: f64, n: usize) -> SimConfig {
        // Generator-stressing workload: light retrieval (k ∈ [50, 100])
        // keeps the retriever pool out of the way so the batching policy
        // is the binding constraint. Rates are stated relative to the
        // static run-to-completion generator capacity (~540 req/s: 32
        // GPU instances × 4 slots / ~0.24 s batch turnaround).
        let trace = TraceConfig {
            rate,
            n,
            slo: Some(2.0),
            k_lo: 50,
            k_hi: 100,
            ..TraceConfig::default()
        };
        let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, 0xC0B1);
        cfg.gen_batching = mode;
        cfg
    }

    #[test]
    fn legacy_mode_is_bit_identical_to_default() {
        use crate::profile::models::GenBatching;
        let a = SimWorld::simulate(apps::vanilla_rag(), gen_cfg(GenBatching::Legacy, 8.0, 200));
        let mut cfg = gen_cfg(GenBatching::Legacy, 8.0, 200);
        cfg.gen_batching = GenBatching::default();
        let b = SimWorld::simulate(apps::vanilla_rag(), cfg);
        assert_eq!(a.report.mean_latency.to_bits(), b.report.mean_latency.to_bits());
        assert_eq!(a.report.p99.to_bits(), b.report.p99.to_bits());
        assert!(a.report.gen.is_none(), "legacy mode records no TTFT/token stats");
    }

    #[test]
    fn continuous_batching_beats_static_at_2x_load() {
        // The tentpole's acceptance claim, pinned deterministically: at
        // ≥2× the static generator capacity, iteration-level batching
        // strictly improves p99 TTFT and goodput over run-to-completion
        // batching — a short answer co-batched with a long one no longer
        // waits out the longest decode, and slots free at EOS instead of
        // at batch completion.
        use crate::profile::models::GenBatching;
        let rate = 2.0 * 540.0;
        let n = 1500;
        let sta = SimWorld::simulate(apps::vanilla_rag(), gen_cfg(GenBatching::Static, rate, n));
        let con =
            SimWorld::simulate(apps::vanilla_rag(), gen_cfg(GenBatching::Continuous, rate, n));
        assert_eq!(sta.report.completed, n as u64);
        assert_eq!(con.report.completed, n as u64);
        let gs = sta.report.gen.expect("static mode records gen stats");
        let gc = con.report.gen.expect("continuous mode records gen stats");
        assert!(
            gc.ttft_p99 < gs.ttft_p99,
            "continuous p99 TTFT {} must beat static {}",
            gc.ttft_p99,
            gs.ttft_p99
        );
        assert!(
            con.report.goodput() > sta.report.goodput(),
            "continuous goodput {} must beat static {}",
            con.report.goodput(),
            sta.report.goodput()
        );
        // The co-batching tax shows up in per-token pace too.
        assert!(gc.tok_p99 < gs.tok_p99, "tok p99 {} vs {}", gc.tok_p99, gs.tok_p99);
    }

    #[test]
    fn continuous_batching_cuts_generator_service_time_under_load() {
        // Moderate load (≈0.75× static capacity, so real multi-request
        // batches form): continuous per-visit generator service must
        // track each request's own decode length, while static
        // attribution carries the batch-max inflation.
        use crate::profile::models::GenBatching;
        let sta = SimWorld::simulate(apps::vanilla_rag(), gen_cfg(GenBatching::Static, 400.0, 800));
        let con =
            SimWorld::simulate(apps::vanilla_rag(), gen_cfg(GenBatching::Continuous, 400.0, 800));
        let ms = sta.report.components["generator"].mean_service();
        let mc = con.report.components["generator"].mean_service();
        assert!(
            mc < ms,
            "continuous mean generator service {mc} must undercut static {ms}"
        );
    }

    #[test]
    fn batching_modes_are_deterministic() {
        use crate::profile::models::GenBatching;
        for mode in [GenBatching::Static, GenBatching::Continuous] {
            let a = SimWorld::simulate(apps::vanilla_rag(), gen_cfg(mode, 400.0, 300));
            let b = SimWorld::simulate(apps::vanilla_rag(), gen_cfg(mode, 400.0, 300));
            assert_eq!(a.report.mean_latency.to_bits(), b.report.mean_latency.to_bits());
            let (ga, gb) = (a.report.gen.unwrap(), b.report.gen.unwrap());
            assert_eq!(ga.ttft_p99.to_bits(), gb.ttft_p99.to_bits());
            assert_eq!(ga.tok_p99.to_bits(), gb.tok_p99.to_bits());
        }
    }

    #[test]
    fn recursive_apps_terminate_under_batching_modes() {
        // Rewrite loops re-enter the generator; both explicit batching
        // modes must still drain every request (slot bookkeeping survives
        // re-entry) on the conditional/recursive reference apps.
        use crate::profile::models::GenBatching;
        for app in ["c-rag", "s-rag", "a-rag"] {
            for mode in [GenBatching::Static, GenBatching::Continuous] {
                let trace =
                    TraceConfig { rate: 8.0, n: 150, slo: Some(4.0), ..TraceConfig::default() };
                let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, 5);
                cfg.gen_batching = mode;
                let r = SimWorld::simulate(apps::by_name(app).unwrap(), cfg);
                assert_eq!(r.report.completed, 150, "{app} under {mode:?}");
            }
        }
    }

    // ---- parallel dataflow (fork/join) ------------------------------------

    #[test]
    fn hybrid_fork_completes_and_beats_its_serialized_twin() {
        // Same trace, same seed, equal resources: overlapping dense
        // retrieval with web search must strictly cut p50 AND p99 over
        // running them back to back — the critical path drops from
        // retr + web to max(retr, web).
        let par = run_point(SystemKind::Harmonia, apps::hybrid_rag(), 8.0, 300, Some(2.0), 17);
        let seq = run_point(
            SystemKind::Harmonia,
            apps::hybrid_rag_sequential(),
            8.0,
            300,
            Some(2.0),
            17,
        );
        assert_eq!(par.report.completed, 300);
        assert_eq!(seq.report.completed, 300);
        assert!(
            par.report.p50 < seq.report.p50,
            "parallel p50 {} vs serial {}",
            par.report.p50,
            seq.report.p50
        );
        assert!(
            par.report.p99 < seq.report.p99,
            "parallel p99 {} vs serial {}",
            par.report.p99,
            seq.report.p99
        );
        // The join barrier records sibling stall on the generator.
        let gen = &par.report.components["generator"];
        assert!(gen.joins > 0, "join releases recorded");
        assert!(gen.join_wait > 0.0, "some branch always waits");
        // Both branches executed once per request.
        assert_eq!(par.report.components["retriever"].executions, 300);
        assert_eq!(par.report.components["websearch"].executions, 300);
        // No fork: no join stats anywhere in the serialized run.
        assert!(seq.report.components.values().all(|c| c.joins == 0));
    }

    #[test]
    fn multiquery_fork_completes_and_beats_its_serialized_twin() {
        let par = run_point(SystemKind::Harmonia, apps::multiquery_rag(3), 8.0, 250, Some(2.0), 19);
        let seq = run_point(
            SystemKind::Harmonia,
            apps::multiquery_rag_sequential(3),
            8.0,
            250,
            Some(2.0),
            19,
        );
        assert_eq!(par.report.completed, 250);
        assert_eq!(seq.report.completed, 250);
        assert!(par.report.p50 < seq.report.p50, "{} vs {}", par.report.p50, seq.report.p50);
        assert!(par.report.p99 < seq.report.p99, "{} vs {}", par.report.p99, seq.report.p99);
        // All three variants do full work in both shapes.
        for i in 0..3 {
            let name = format!("retriever_q{i}");
            assert_eq!(par.report.components[&name].executions, 250, "{name}");
            assert_eq!(seq.report.components[&name].executions, 250, "{name}");
        }
    }

    #[test]
    fn fork_runs_are_deterministic() {
        for app in ["hybrid-rag", "mq-rag"] {
            let a = quick(SystemKind::Harmonia, app, 12.0, 150);
            let b = quick(SystemKind::Harmonia, app, 12.0, 150);
            assert_eq!(a.report.completed, b.report.completed, "{app}");
            assert_eq!(
                a.report.mean_latency.to_bits(),
                b.report.mean_latency.to_bits(),
                "{app}"
            );
            assert_eq!(a.report.p99.to_bits(), b.report.p99.to_bits(), "{app}");
        }
    }

    /// Racing fixture: source →fork→ {retriever ∥ websearch} with a
    /// FirstK(1) join at the generator — winner takes all, loser
    /// cancelled.
    fn racing_rag() -> crate::spec::PipelineGraph {
        use crate::spec::{ComponentKind, JoinSpec, PipelineBuilder, ResourceKind};
        let mut b = PipelineBuilder::new("racing-rag");
        let retr = b
            .component("retriever", ComponentKind::Retriever)
            .resources(&[(ResourceKind::Cpu, 8.0), (ResourceKind::Ram, 112.0)])
            .add();
        let web = b
            .component("websearch", ComponentKind::WebSearch)
            .resources(&[(ResourceKind::Cpu, 1.0)])
            .add();
        let gen = b
            .component("generator", ComponentKind::Generator)
            .resources(&[(ResourceKind::Gpu, 1.0)])
            .join(JoinSpec::first_k(1))
            .add();
        b.fork(b.source(), &[retr, web]);
        b.edge(retr, gen, 1.0);
        b.edge(web, gen, 1.0);
        b.edge_to_sink(gen, 1.0);
        b.build().expect("racing-rag is valid")
    }

    #[test]
    fn first_k_races_cancel_losers_without_corrupting_state() {
        let r = run_point(SystemKind::Harmonia, racing_rag(), 12.0, 300, Some(2.0), 23);
        assert_eq!(r.report.completed, 300, "every request completes despite cancellations");
        // The race means the generator starts at the FASTER branch's
        // finish: p50 must beat the All-join hybrid (which waits for the
        // slower sibling) on the same trace.
        let all = run_point(SystemKind::Harmonia, apps::hybrid_rag(), 12.0, 300, Some(2.0), 23);
        assert!(
            r.report.p50 < all.report.p50,
            "FirstK(1) p50 {} vs All-join {}",
            r.report.p50,
            all.report.p50
        );
        // FirstK(1): the winner arrives alone — zero sibling stall.
        assert!((r.report.components["generator"].join_wait - 0.0).abs() < 1e-12);
        // Determinism under cancellation.
        let r2 = run_point(SystemKind::Harmonia, racing_rag(), 12.0, 300, Some(2.0), 23);
        assert_eq!(r.report.mean_latency.to_bits(), r2.report.mean_latency.to_bits());
    }

    #[test]
    fn first_k_cancellation_is_safe_with_streaming_branches() {
        // Regression for the streamed-zombie race: a cancelled branch
        // whose streamable stage already pre-dispatched its next hop
        // must stay cancelled when that in-flight event lands — the
        // cancellation mark may only be consumed once no streamed
        // dispatch is outstanding.
        use crate::spec::{ComponentKind, JoinSpec, PipelineBuilder, ResourceKind};
        let mut b = PipelineBuilder::new("racing-stream");
        let retr = b
            .component("retriever", ComponentKind::Retriever)
            .resources(&[(ResourceKind::Cpu, 8.0), (ResourceKind::Ram, 112.0)])
            .streamable(true)
            .add();
        let grader = b
            .component("grader", ComponentKind::Grader)
            .resources(&[(ResourceKind::Gpu, 1.0)])
            .add();
        let web = b
            .component("websearch", ComponentKind::WebSearch)
            .resources(&[(ResourceKind::Cpu, 1.0)])
            .add();
        let gen = b
            .component("generator", ComponentKind::Generator)
            .resources(&[(ResourceKind::Gpu, 1.0)])
            .join(JoinSpec::first_k(1))
            .add();
        b.fork(b.source(), &[retr, web]);
        b.edge(retr, grader, 1.0);
        b.edge(grader, gen, 1.0);
        b.edge(web, gen, 1.0);
        b.edge_to_sink(gen, 1.0);
        let g = b.build().expect("racing-stream is valid");
        // The two-hop streamable branch usually loses to the single-hop
        // web branch, so cancellations land mid-stream routinely.
        let r = run_point(SystemKind::Harmonia, g.clone(), 12.0, 300, Some(2.0), 41);
        assert_eq!(r.report.completed, 300);
        assert_eq!(r.residual_bindings, 0);
        let r2 = run_point(SystemKind::Harmonia, g, 12.0, 300, Some(2.0), 41);
        assert_eq!(r.report.mean_latency.to_bits(), r2.report.mean_latency.to_bits());
    }

    #[test]
    fn fork_apps_leak_no_router_bindings_or_slots() {
        // Slot-leak audit: every terminal path — completion, shed,
        // degraded completion, cancelled fork loser — must release its
        // stateful bindings; nothing may be left bound once the run
        // drains.
        let cases: Vec<crate::sim::SimResult> = vec![
            quick(SystemKind::Harmonia, "s-rag", 16.0, 150), // stateful loop
            quick(SystemKind::Harmonia, "hybrid-rag", 12.0, 150),
            run_point(SystemKind::Harmonia, racing_rag(), 12.0, 200, Some(2.0), 29),
        ];
        for r in cases {
            assert_eq!(r.residual_bindings, 0, "router bindings leaked");
        }
        // Shed-at-admission and degraded completions (overload defense).
        let trace = TraceConfig { rate: 1440.0, n: 800, slo: Some(2.0), ..TraceConfig::default() };
        let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, 0xA11);
        cfg.sched = crate::sched::SchedConfig::overload_defense();
        let r = SimWorld::simulate(apps::self_rag(), cfg);
        assert_eq!(r.report.completed + r.report.shed, 800);
        assert_eq!(r.residual_bindings, 0, "shed/degraded paths leaked bindings");
    }

    #[test]
    fn fork_apps_work_under_batching_modes_and_monolith() {
        use crate::profile::models::GenBatching;
        // The generator-as-join composes with explicit batching modes.
        for mode in [GenBatching::Static, GenBatching::Continuous] {
            let trace = TraceConfig { rate: 8.0, n: 120, slo: Some(4.0), ..TraceConfig::default() };
            let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, 31);
            cfg.gen_batching = mode;
            let r = SimWorld::simulate(apps::hybrid_rag(), cfg);
            assert_eq!(r.report.completed, 120, "{mode:?}");
            assert!(r.report.gen.is_some(), "{mode:?} records TTFT");
        }
        // LangChain-style monolith serializes the fork inside the
        // replica — still completes, and with no join stalls recorded.
        let r = run_point(SystemKind::LangChain, apps::hybrid_rag(), 4.0, 100, Some(4.0), 37);
        assert_eq!(r.report.completed, 100);
        assert!(r.report.components.values().all(|c| c.joins == 0));
    }

    #[test]
    fn component_breakdown_recorded() {
        let r = quick(SystemKind::Harmonia, "c-rag", 8.0, 200);
        for comp in ["retriever", "grader", "generator"] {
            assert!(
                r.report.components.contains_key(comp),
                "missing {comp} in breakdown"
            );
        }
        // Grader must be the costliest per-visit GPU stage (C-RAG's
        // bottleneck, Fig. 10).
        let g = r.report.components["grader"].mean_service();
        let gen = r.report.components["generator"].mean_service();
        assert!(g > gen, "grader {g} vs generator {gen}");
    }

    // ---- prefill/decode disaggregation -------------------------------------

    /// Generator-bound workload (light retrieval) under continuous
    /// batching — the collocated arm of every placement comparison, so
    /// both arms record TTFT through the same iteration-level engine.
    fn place_cfg(rate: f64, n: usize, seed: u64) -> SimConfig {
        let trace = TraceConfig {
            rate,
            n,
            slo: Some(2.0),
            k_lo: 50,
            k_hi: 100,
            ..TraceConfig::default()
        };
        let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, seed);
        cfg.gen_batching = GenBatching::Continuous;
        cfg
    }

    fn disaggregated(mut cfg: SimConfig, kv: KvTransferModel, hit: f64) -> SimConfig {
        cfg.gen_placement = GenPlacement::Disaggregated;
        cfg.kv_transfer = kv;
        cfg.kv_prefix_hit_rate = hit;
        cfg
    }

    #[test]
    fn disaggregation_with_prefix_cache_cuts_p99_ttft_on_repeat_heavy_load() {
        // The tentpole's acceptance claim, pinned deterministically. The
        // operating point sits between the two capacities: a repeat-heavy
        // Zipf context pool gives the prefix cache a high longest-prefix
        // hit rate, which lifts the disaggregated configuration's
        // generator capacity above the collocated ceiling (~1000 req/s on
        // this workload). At 1400 req/s the collocated pool's backlog
        // grows without bound while the split pools shed prefill work
        // into the cache — p99 TTFT must strictly separate.
        let hit = crate::profile::models::zipf_hit_rate(1.3, 0.9, 4096, 2048);
        assert!(hit > 0.8, "workload should be repeat-heavy, got {hit}");
        let (rate, n, seed) = (1400.0, 3000, 0xD15A);
        let col = SimWorld::simulate(apps::vanilla_rag(), place_cfg(rate, n, seed));
        let dis = SimWorld::simulate(
            apps::vanilla_rag(),
            disaggregated(place_cfg(rate, n, seed), KvTransferModel::default(), hit),
        );
        assert_eq!(col.report.completed, n as u64);
        assert_eq!(dis.report.completed, n as u64);
        assert!(col.report.disagg.is_none(), "collocated runs carry no disagg section");
        let gc = col.report.gen.expect("collocated continuous records TTFT");
        let gd = dis.report.gen.expect("disaggregated records TTFT");
        assert!(
            gd.ttft_p99 < gc.ttft_p99,
            "disagg + prefix cache p99 TTFT {} must beat collocated {}",
            gd.ttft_p99,
            gc.ttft_p99
        );
        let d = dis.report.disagg.expect("disaggregated run reports the section");
        assert_eq!(d.handoffs, n as u64, "one handoff per generator visit");
        assert!(d.prefill_instances >= 1 && d.decode_instances >= 1);
        assert!(
            d.decode_instances > d.prefill_instances,
            "decode dominates the split: {} vs {}",
            d.decode_instances,
            d.prefill_instances
        );
        assert!(
            (d.kv_prefix.hit_rate() - hit).abs() < 0.05,
            "observed prefix hit rate {} vs modeled {hit}",
            d.kv_prefix.hit_rate()
        );
        assert!(d.mean_transfer() > 0.0);
    }

    #[test]
    fn collocated_wins_when_kv_transfer_dominates() {
        // The other direction of the RAGO figure: on a slow fabric
        // (scale ×200 ≈ 170 ms per handoff) every disaggregated visit
        // pays a transfer tax no cache can refund — collocated must win
        // both TTFT and end-to-end latency at a load both can carry.
        let slow = KvTransferModel { scale: 200.0, ..KvTransferModel::default() };
        let (rate, n, seed) = (400.0, 800, 0xD15A);
        let col = SimWorld::simulate(apps::vanilla_rag(), place_cfg(rate, n, seed));
        let dis =
            SimWorld::simulate(apps::vanilla_rag(), disaggregated(place_cfg(rate, n, seed), slow, 0.0));
        assert_eq!(col.report.completed, n as u64);
        assert_eq!(dis.report.completed, n as u64);
        let gc = col.report.gen.unwrap();
        let gd = dis.report.gen.unwrap();
        assert!(
            gc.ttft_p99 < gd.ttft_p99,
            "collocated p99 TTFT {} must beat slow-fabric disagg {}",
            gc.ttft_p99,
            gd.ttft_p99
        );
        assert!(
            col.report.mean_latency < dis.report.mean_latency,
            "collocated mean {} vs disagg {}",
            col.report.mean_latency,
            dis.report.mean_latency
        );
        let d = dis.report.disagg.unwrap();
        assert!(
            d.mean_transfer() > 0.1 && d.mean_transfer() < 0.25,
            "mean transfer {} should sit near scale × (base + per_tok · prompt)",
            d.mean_transfer()
        );
        // No prefix cache: the counters never moved and no rng was drawn.
        assert_eq!(d.kv_prefix.lookups(), 0);
    }

    #[test]
    fn disaggregated_runs_are_deterministic() {
        let run = || {
            SimWorld::simulate(
                apps::vanilla_rag(),
                disaggregated(place_cfg(700.0, 600, 0xD15A), KvTransferModel::default(), 0.5),
            )
        };
        let (a, b) = (run(), run());
        assert_eq!(a.report.completed, b.report.completed);
        assert_eq!(a.report.mean_latency.to_bits(), b.report.mean_latency.to_bits());
        assert_eq!(a.report.p99.to_bits(), b.report.p99.to_bits());
        let (ga, gb) = (a.report.gen.unwrap(), b.report.gen.unwrap());
        assert_eq!(ga.ttft_p99.to_bits(), gb.ttft_p99.to_bits());
        let (da, db) = (a.report.disagg.unwrap(), b.report.disagg.unwrap());
        assert_eq!(da.handoffs, db.handoffs);
        assert_eq!(da.transfer_total.to_bits(), db.transfer_total.to_bits());
    }

    #[test]
    fn disaggregation_composes_with_forks_loops_and_races() {
        // The handoff chain must survive every control-flow shape:
        // conditional branches, stateful rewrite loops re-entering the
        // generator, All-joins landing *on* the generator, and FirstK
        // losers cancelled mid-handoff.
        for app in ["c-rag", "s-rag", "hybrid-rag"] {
            let cfg = disaggregated(place_cfg(8.0, 150, 0xD15A), KvTransferModel::default(), 0.3);
            let r = SimWorld::simulate(apps::by_name(app).unwrap(), cfg);
            assert_eq!(r.report.completed, 150, "{app}");
            assert_eq!(r.residual_bindings, 0, "{app} leaked bindings");
            assert!(r.report.disagg.is_some(), "{app} reports the section");
        }
        let cfg = disaggregated(place_cfg(12.0, 200, 0xD15A), KvTransferModel::default(), 0.3);
        let r = SimWorld::simulate(racing_rag(), cfg);
        assert_eq!(r.report.completed, 200, "FirstK race under disaggregation");
        assert_eq!(r.residual_bindings, 0);
    }

    #[test]
    fn runs_report_event_counts_and_never_clamp() {
        // The perf bench's numerator must be populated, and a healthy
        // model never schedules into the past — `clamped` staying at 0
        // across every control-flow shape (forks, races, disaggregation,
        // monoliths) is the satellite guarantee that makes the counter a
        // usable tripwire.
        let runs = vec![
            quick(SystemKind::Harmonia, "v-rag", 8.0, 200),
            quick(SystemKind::Harmonia, "hybrid-rag", 12.0, 150),
            quick(SystemKind::LangChain, "v-rag", 4.0, 100),
            run_point(SystemKind::Harmonia, racing_rag(), 12.0, 200, Some(2.0), 23),
            SimWorld::simulate(
                apps::vanilla_rag(),
                disaggregated(place_cfg(700.0, 400, 0xD15A), KvTransferModel::default(), 0.5),
            ),
        ];
        for r in runs {
            assert!(r.events > 0, "event count must be recorded");
            assert!(
                r.events >= r.report.completed,
                "at least one event per completed request"
            );
            assert_eq!(r.clamped, 0, "no schedule may ask for a past time");
        }
    }
}
