//! Discrete-event cluster simulator — the testbed substitute.
//!
//! Reproduces the paper's 4×8-A100 experiments on one machine by driving
//! the real coordinator policy code over calibrated latency models:
//! [`des`] provides the event core, [`cluster`] the machines/placement,
//! [`simrun`] the serving world. The serving **baselines** also live in
//! [`simrun`]: `SystemKind::LangChain` (monolithic whole-pipeline
//! replicas) and `SystemKind::Haystack` (task-centric, idle-first, FIFO)
//! — there is no separate baselines module.

pub mod cluster;
pub mod des;
pub mod simrun;

pub use cluster::Cluster;
pub use simrun::{run_point, AblationFlags, SimConfig, SimResult, SimWorld, SystemKind};
