//! Discrete-event cluster simulator — the testbed substitute.
//!
//! Reproduces the paper's 4×8-A100 experiments on one machine by driving
//! the real coordinator policy code over calibrated latency models:
//! [`des`] provides the event core, [`cluster`] the machines/placement,
//! [`simrun`] the serving world (Harmonia + both baselines).

pub mod cluster;
pub mod des;
pub mod simrun;

pub use cluster::Cluster;
pub use simrun::{run_point, AblationFlags, SimConfig, SimResult, SimWorld, SystemKind};
