//! Simulated cluster: machines with CPU/GPU/RAM capacity, first-fit
//! placement, and co-location accounting (Table 3: heterogeneous
//! components co-locate with <1.1% interference).

use crate::spec::graph::ResourceKind;

/// One machine's remaining capacity.
#[derive(Clone, Debug)]
pub struct Machine {
    pub cpu: f64,
    pub gpu: f64,
    pub ram: f64,
    /// Does this machine currently host CPU-bound work / GPU-bound work?
    pub hosts_cpu_comp: bool,
    pub hosts_gpu_comp: bool,
}

impl Machine {
    pub fn new(cpu: f64, gpu: f64, ram: f64) -> Self {
        Machine { cpu, gpu, ram, hosts_cpu_comp: false, hosts_gpu_comp: false }
    }

    fn remaining(&self, k: ResourceKind) -> f64 {
        match k {
            ResourceKind::Cpu => self.cpu,
            ResourceKind::Gpu => self.gpu,
            ResourceKind::Ram => self.ram,
        }
    }

    fn take(&mut self, k: ResourceKind, amt: f64) {
        match k {
            ResourceKind::Cpu => self.cpu -= amt,
            ResourceKind::Gpu => self.gpu -= amt,
            ResourceKind::Ram => self.ram -= amt,
        }
    }

    fn give(&mut self, k: ResourceKind, amt: f64) {
        self.take(k, -amt);
    }
}

/// Measured co-location slowdown (Table 3 reports < 1.1% variance; we
/// model 0.5%).
pub const COLOCATION_SLOWDOWN: f64 = 1.005;

/// The simulator's calibration points for sharded (scatter-gather) and
/// cached (request-memoizing) components. The models themselves live
/// with the other calibrated latency models in `profile::models` so the
/// deploy-time profiler does not depend on the simulator; re-exported
/// here because the DES applies them to every sampled service time.
pub use crate::profile::models::{
    cache_service_factor, quantized_service_factor, shard_service_factor, zipf_hit_rate,
    CACHE_HIT_COST_FRAC, QUANTIZED_SERVICE_FRAC, SHARD_MERGE_FRAC, SHARD_SERIAL_FRAC,
};

/// The cluster: a bag of machines plus placement bookkeeping.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub machines: Vec<Machine>,
}

/// A successful placement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub machine: usize,
    /// Whether this instance shares its machine with a different
    /// resource-class component (co-location).
    pub colocated: bool,
}

impl Cluster {
    /// The paper's testbed: 4 machines × (32 CPU cores, 8 GPUs, 256 GiB).
    pub fn paper_testbed() -> Cluster {
        Cluster {
            machines: (0..4).map(|_| Machine::new(32.0, 8.0, 256.0)).collect(),
        }
    }

    pub fn uniform(n: usize, cpu: f64, gpu: f64, ram: f64) -> Cluster {
        Cluster { machines: (0..n).map(|_| Machine::new(cpu, gpu, ram)).collect() }
    }

    /// Total capacity per resource (budget vector for the LP).
    pub fn budgets(&self) -> Vec<(ResourceKind, f64)> {
        let mut cpu = 0.0;
        let mut gpu = 0.0;
        let mut ram = 0.0;
        for m in &self.machines {
            cpu += m.cpu;
            gpu += m.gpu;
            ram += m.ram;
        }
        vec![(ResourceKind::Cpu, cpu), (ResourceKind::Gpu, gpu), (ResourceKind::Ram, ram)]
    }

    /// First-fit placement of an instance demanding `demands`.
    /// `gpu_bound` tags the co-location class.
    pub fn place(&mut self, demands: &[(ResourceKind, f64)], gpu_bound: bool) -> Option<Placement> {
        'outer: for (mi, m) in self.machines.iter_mut().enumerate() {
            for &(k, amt) in demands {
                if m.remaining(k) + 1e-9 < amt {
                    continue 'outer;
                }
            }
            for &(k, amt) in demands {
                m.take(k, amt);
            }
            let colocated = if gpu_bound { m.hosts_cpu_comp } else { m.hosts_gpu_comp };
            if gpu_bound {
                m.hosts_gpu_comp = true;
            } else {
                m.hosts_cpu_comp = true;
            }
            return Some(Placement { machine: mi, colocated });
        }
        None
    }

    /// Release an instance's resources.
    pub fn release(&mut self, placement: Placement, demands: &[(ResourceKind, f64)]) {
        let m = &mut self.machines[placement.machine];
        for &(k, amt) in demands {
            m.give(k, amt);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_budgets() {
        let c = Cluster::paper_testbed();
        let b = c.budgets();
        assert!(b.contains(&(ResourceKind::Cpu, 128.0)));
        assert!(b.contains(&(ResourceKind::Gpu, 32.0)));
        assert!(b.contains(&(ResourceKind::Ram, 1024.0)));
    }

    #[test]
    fn first_fit_places_and_exhausts() {
        let mut c = Cluster::uniform(1, 16.0, 2.0, 64.0);
        let gpu_demand = [(ResourceKind::Gpu, 1.0)];
        assert!(c.place(&gpu_demand, true).is_some());
        assert!(c.place(&gpu_demand, true).is_some());
        assert!(c.place(&gpu_demand, true).is_none(), "only 2 GPUs");
    }

    #[test]
    fn colocation_detected() {
        let mut c = Cluster::uniform(1, 16.0, 2.0, 256.0);
        let cpu_demand = [(ResourceKind::Cpu, 8.0), (ResourceKind::Ram, 112.0)];
        let gpu_demand = [(ResourceKind::Gpu, 1.0)];
        let p1 = c.place(&cpu_demand, false).unwrap();
        assert!(!p1.colocated);
        let p2 = c.place(&gpu_demand, true).unwrap();
        assert!(p2.colocated, "GPU instance shares machine with retriever");
    }

    #[test]
    fn release_restores_capacity() {
        let mut c = Cluster::uniform(1, 8.0, 1.0, 64.0);
        let d = [(ResourceKind::Gpu, 1.0)];
        let p = c.place(&d, true).unwrap();
        assert!(c.place(&d, true).is_none());
        c.release(p, &d);
        assert!(c.place(&d, true).is_some());
    }

    #[test]
    fn shard_factor_identity_at_one_shard() {
        assert_eq!(shard_service_factor(1), 1.0);
        assert_eq!(shard_service_factor(0), 1.0, "0 clamps to 1");
    }

    #[test]
    fn shard_factor_speedup_is_sublinear_and_monotone_in_useful_range() {
        let mut prev = shard_service_factor(1);
        for s in 2..=8 {
            let f = shard_service_factor(s);
            assert!(f < prev, "factor must fall up to 8 shards: {s} → {f}");
            // Sublinear: never better than perfect 1/S scaling.
            assert!(f > 1.0 / s as f64, "superlinear at {s}: {f}");
            prev = f;
        }
    }

    #[test]
    fn shard_factor_overhead_dominates_at_extreme_fanout() {
        // Past the sweet spot the merge term wins: more shards get slower.
        assert!(shard_service_factor(64) > shard_service_factor(10));
        // But even extreme fan-out never exceeds the unsharded baseline
        // within a sane range.
        assert!(shard_service_factor(64) < 1.0);
    }

    #[test]
    fn multi_resource_demand_must_fit_entirely() {
        let mut c = Cluster::uniform(2, 8.0, 1.0, 100.0);
        // Fits CPU but not RAM on machine 0 after first placement.
        let d = [(ResourceKind::Cpu, 4.0), (ResourceKind::Ram, 80.0)];
        let p1 = c.place(&d, false).unwrap();
        let p2 = c.place(&d, false).unwrap();
        assert_ne!(p1.machine, p2.machine, "second must spill to machine 1");
        assert!(c.place(&d, false).is_none());
    }
}
