//! Discrete-event core: a time-ordered event queue with stable FIFO
//! tie-breaking (deterministic runs for fixed seeds).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event.
struct Scheduled<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Event queue with a virtual clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute time `t` (clamped to now).
    pub fn schedule(&mut self, t: f64, event: E) {
        let t = t.max(self.now);
        self.seq += 1;
        self.heap.push(Scheduled { time: t, seq: self.seq, event });
    }

    /// Schedule after a delay.
    pub fn schedule_in(&mut self, dt: f64, event: E) {
        debug_assert!(dt >= 0.0);
        self.schedule(self.now + dt, event);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(f64, E)> {
        let s = self.heap.pop()?;
        debug_assert!(s.time >= self.now);
        self.now = s.time;
        self.processed += 1;
        Some((s.time, s.event))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "first");
        q.schedule(1.0, "second");
        q.schedule(1.0, "third");
        let order: Vec<&str> = std::iter::from_fn(|| q.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["first", "second", "third"]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, 1u8);
        q.schedule(2.0, 2u8);
        let (t1, _) = q.next().unwrap();
        assert_eq!(t1, 2.0);
        assert_eq!(q.now(), 2.0);
        // Scheduling in the past clamps to now.
        q.schedule(1.0, 3u8);
        let (t2, e) = q.next().unwrap();
        assert_eq!((t2, e), (2.0, 3u8));
        let (t3, _) = q.next().unwrap();
        assert_eq!(t3, 5.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "x");
        q.next();
        q.schedule_in(0.5, "y");
        let (t, _) = q.next().unwrap();
        assert!((t - 2.5).abs() < 1e-12);
    }
}
