//! Discrete-event core: a time-ordered event list with stable FIFO
//! tie-breaking (deterministic runs for fixed seeds).
//!
//! The event list is a calendar queue (R. Brown, "Calendar Queues: A
//! Fast O(1) Priority Queue Implementation", CACM 1988): a ring of
//! fixed-width time buckets, each holding a small binary heap. Inserts
//! hash the event time to its bucket in O(1); pops walk the ring one
//! virtual "day" at a time, taking only events whose day matches the
//! cursor. With the bucket count tracking the population (rebuilds on
//! 4x growth / shrink), buckets stay tiny and both operations run in
//! amortized near-constant time — the per-event `O(log n)` of a single
//! global `BinaryHeap` was the DES's hottest edge once scenarios
//! reached tens of millions of events (ROADMAP item 4).
//!
//! Ordering is `f64::total_cmp` over `(time, seq)`, and `schedule`
//! rejects non-finite times loudly: a NaN service sample now surfaces
//! as a diagnosable panic at the insertion site instead of silently
//! scrambling pop order (the old `partial_cmp(..).unwrap_or(Equal)`
//! hazard). For the finite times that remain, `total_cmp` agrees with
//! `partial_cmp` exactly, and equal times always land in the same
//! bucket — so the monotone `seq` reproduces the old global heap's
//! FIFO tie order bit-for-bit and golden traces replay unchanged.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event.
struct Scheduled<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time) == Ordering::Equal && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. Total
        // order: non-finite times never get past `schedule`.
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Ring size bounds: small enough to stay cache-friendly when nearly
/// empty, capped so a 10M-event backlog doesn't allocate a bucket per
/// event.
const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 20;

/// Event queue with a virtual clock (calendar-queue event list).
pub struct EventQueue<E> {
    /// Ring of day buckets; bucket `(vday % len)` holds the events of
    /// that virtual day (and of days a whole lap ahead, filtered on pop).
    buckets: Vec<BinaryHeap<Scheduled<E>>>,
    /// Bucket width in seconds of virtual time.
    width: f64,
    /// Cursor: the virtual day currently being drained. Invariant:
    /// `day <= vday(t)` for every stored event (times are clamped to
    /// `now`, and `now` never runs ahead of the cursor's day).
    day: u64,
    /// Total stored events across all buckets.
    len: usize,
    now: f64,
    seq: u64,
    processed: u64,
    clamped: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            buckets: (0..MIN_BUCKETS).map(|_| BinaryHeap::new()).collect(),
            width: 1.0,
            day: 0,
            len: 0,
            now: 0.0,
            seq: 0,
            processed: 0,
            clamped: 0,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// How many schedules asked for a time in the past and were clamped
    /// to `now`. Healthy models never do; a nonzero count is the
    /// tell-tale of a latency model emitting negative durations.
    pub fn clamped(&self) -> u64 {
        self.clamped
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// The virtual day a time falls in. The same division is used at
    /// insert and pop so membership tests can never drift; the cast
    /// saturates for times far beyond any simulated horizon.
    #[inline]
    fn vday(&self, t: f64) -> u64 {
        (t / self.width) as u64
    }

    /// Schedule `event` at absolute time `t`.
    ///
    /// Panics on non-finite `t`: a NaN/inf event time is always an
    /// upstream model bug, and letting it into the ordering would
    /// corrupt pop order silently. Past times are clamped to `now`
    /// (and counted — see [`EventQueue::clamped`]).
    pub fn schedule(&mut self, t: f64, event: E) {
        assert!(t.is_finite(), "non-finite event time {t}: bad model input");
        let t = if t < self.now {
            self.clamped += 1;
            self.now
        } else {
            t
        };
        self.seq += 1;
        let b = (self.vday(t) % self.buckets.len() as u64) as usize;
        self.buckets[b].push(Scheduled { time: t, seq: self.seq, event });
        self.len += 1;
        if self.len > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            self.rebuild();
        }
    }

    /// Schedule after a delay.
    pub fn schedule_in(&mut self, dt: f64, event: E) {
        debug_assert!(dt >= 0.0);
        self.schedule(self.now + dt, event);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(f64, E)> {
        if self.len == 0 {
            return None;
        }
        let n = self.buckets.len();
        // Walk the ring from the cursor's day: an event in the cursor
        // bucket belongs to the current day only if its own virtual day
        // matches (the bucket also holds events a full lap ahead).
        for _ in 0..n {
            let b = (self.day % n as u64) as usize;
            if let Some(head) = self.buckets[b].peek() {
                if self.vday(head.time) == self.day {
                    return Some(self.take(b));
                }
            }
            self.day = self.day.saturating_add(1);
        }
        // A whole fruitless lap: the next event is more than one lap
        // ahead (sparse gap). Find the earliest head directly and jump
        // the cursor to its day. Equal times share a bucket, so the
        // per-bucket heads are strictly ordered by time here.
        let mut best: Option<(usize, f64, u64)> = None;
        for (b, heap) in self.buckets.iter().enumerate() {
            if let Some(head) = heap.peek() {
                let better = match &best {
                    None => true,
                    Some(&(_, t, s)) => {
                        head.time.total_cmp(&t).then_with(|| head.seq.cmp(&s)) == Ordering::Less
                    }
                };
                if better {
                    best = Some((b, head.time, head.seq));
                }
            }
        }
        let (b, t, _) = best.expect("len > 0 but no bucket head");
        self.day = self.vday(t);
        Some(self.take(b))
    }

    /// Pop the head of bucket `b`, advance the clock, and shrink the
    /// ring if the population has collapsed.
    fn take(&mut self, b: usize) -> (f64, E) {
        let s = self.buckets[b].pop().expect("take from empty bucket");
        debug_assert!(s.time >= self.now);
        self.now = s.time;
        self.len -= 1;
        self.processed += 1;
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 4 {
            self.rebuild();
        }
        (s.time, s.event)
    }

    /// Resize the ring to track the population and re-fit the bucket
    /// width to the current event-time span, then re-insert everything.
    /// O(n log) but amortized away by the 4x growth/shrink thresholds.
    fn rebuild(&mut self) {
        let mut all: Vec<Scheduled<E>> = Vec::with_capacity(self.len);
        for heap in &mut self.buckets {
            all.extend(heap.drain());
        }
        debug_assert_eq!(all.len(), self.len);
        if all.len() >= 2 {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for s in &all {
                lo = lo.min(s.time);
                hi = hi.max(s.time);
            }
            if hi > lo {
                // Aim for ~1 event per bucket across the live span.
                self.width = ((hi - lo) / all.len() as f64).max(1e-9);
            }
        }
        let n = all.len().next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        if n != self.buckets.len() {
            self.buckets = (0..n).map(|_| BinaryHeap::new()).collect();
        }
        // The cursor restarts at the *clock's* day, not the min event's:
        // future inserts land anywhere in `[now, ..)` and the invariant
        // `day <= vday(t)` must keep holding for them too.
        self.day = (self.now / self.width) as u64;
        for s in all {
            let b = (self.vday(s.time) % n as u64) as usize;
            self.buckets[b].push(s);
        }
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "first");
        q.schedule(1.0, "second");
        q.schedule(1.0, "third");
        let order: Vec<&str> = std::iter::from_fn(|| q.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["first", "second", "third"]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, 1u8);
        q.schedule(2.0, 2u8);
        let (t1, _) = q.next().unwrap();
        assert_eq!(t1, 2.0);
        assert_eq!(q.now(), 2.0);
        // Scheduling in the past clamps to now.
        q.schedule(1.0, 3u8);
        let (t2, e) = q.next().unwrap();
        assert_eq!((t2, e), (2.0, 3u8));
        let (t3, _) = q.next().unwrap();
        assert_eq!(t3, 5.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "x");
        q.next();
        q.schedule_in(0.5, "y");
        let (t, _) = q.next().unwrap();
        assert!((t - 2.5).abs() < 1e-12);
    }

    #[test]
    fn past_schedules_are_clamped_and_counted() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "a");
        assert_eq!(q.clamped(), 0);
        q.next();
        q.schedule(0.5, "late");
        q.schedule(1.9, "also late");
        q.schedule(2.0, "on time");
        assert_eq!(q.clamped(), 2);
        // Clamped events still pop, at `now`, in FIFO order.
        assert_eq!(q.next(), Some((2.0, "late")));
        assert_eq!(q.next(), Some((2.0, "also late")));
        assert_eq!(q.next(), Some((2.0, "on time")));
    }

    #[test]
    fn non_finite_times_are_rejected() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let caught = std::panic::catch_unwind(|| {
                let mut q = EventQueue::new();
                q.schedule(bad, ());
            });
            assert!(caught.is_err(), "schedule({bad}) must panic");
        }
    }

    /// The pre-calendar event list, kept verbatim as the test oracle:
    /// one global `BinaryHeap` with the old comparator. Only finite
    /// times reach it, where `partial_cmp` and `total_cmp` agree — the
    /// oracle match below *is* the bit-identity argument for the golden
    /// traces.
    struct HeapOracle {
        heap: BinaryHeap<Scheduled<u32>>,
        now: f64,
        seq: u64,
    }

    impl HeapOracle {
        fn new() -> Self {
            HeapOracle { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
        }
        fn schedule(&mut self, t: f64, event: u32) {
            let t = t.max(self.now);
            self.seq += 1;
            self.heap.push(Scheduled { time: t, seq: self.seq, event });
        }
        fn next(&mut self) -> Option<(f64, u32)> {
            let s = self.heap.pop()?;
            self.now = s.time;
            Some((s.time, s.event))
        }
    }

    /// Random interleavings of schedules and pops, with time profiles
    /// chosen to stress every calendar path: dense ties (FIFO order
    /// across rebuilds), bucket-boundary clusters, sparse multi-lap
    /// jumps (ring rollover + cursor jump), and enough volume to force
    /// both grow and shrink rebuilds.
    #[test]
    fn matches_binary_heap_oracle_on_random_workloads() {
        property("calendar queue == BinaryHeap oracle", 60, |g| {
            let mut q = EventQueue::new();
            let mut oracle = HeapOracle::new();
            let mut id = 0u32;
            let profile = g.usize(0, 3);
            let ops = g.usize(50, 400);
            for _ in 0..ops {
                let burst = g.usize(1, 12);
                for _ in 0..burst {
                    let dt = match profile {
                        // Dense ties on a coarse grid.
                        0 => g.usize(0, 3) as f64 * 0.5,
                        // Bucket-boundary clusters around integer days.
                        1 => g.usize(0, 8) as f64 + if g.bool() { 1e-12 } else { -1e-12 },
                        // Sparse: long dead gaps between events.
                        2 => g.usize(0, 5) as f64 * 1000.0,
                        // Mixed magnitudes.
                        _ => g.f64(0.0, 50.0),
                    };
                    let t = oracle.now + dt.max(0.0);
                    q.schedule(t, id);
                    oracle.schedule(t, id);
                    id += 1;
                }
                let pops = g.usize(0, burst + 2);
                for _ in 0..pops {
                    let got = q.next();
                    let want = oracle.next();
                    match (got, want) {
                        (None, None) => {}
                        (Some((tg, eg)), Some((tw, ew))) => {
                            assert_eq!(tg.to_bits(), tw.to_bits(), "time diverged from oracle");
                            assert_eq!(eg, ew, "payload diverged from oracle at t={tg}");
                        }
                        (got, want) => panic!("presence diverged: {got:?} vs {want:?}"),
                    }
                }
            }
            // Drain both to the end.
            loop {
                match (q.next(), oracle.next()) {
                    (None, None) => break,
                    (Some((tg, eg)), Some((tw, ew))) => {
                        assert_eq!(tg.to_bits(), tw.to_bits());
                        assert_eq!(eg, ew);
                    }
                    (got, want) => panic!("drain diverged: {got:?} vs {want:?}"),
                }
            }
            assert_eq!(q.len(), 0);
            assert!(q.is_empty());
        });
    }

    #[test]
    fn mass_ties_stay_fifo_across_rebuilds() {
        let mut q = EventQueue::new();
        // Enough spread events to trigger grow rebuilds, interleaved
        // with a large tied cohort whose FIFO order must survive them.
        for i in 0..200u32 {
            q.schedule(10.0, 1000 + i); // the tied cohort
            q.schedule(i as f64 * 0.01, i); // spread filler (all < 10.0)
        }
        // Filler pops first, in time order.
        for i in 0..200u32 {
            let (_, e) = q.next().unwrap();
            assert_eq!(e, i);
        }
        // Then the cohort, in exact insertion order.
        for i in 0..200u32 {
            let (t, e) = q.next().unwrap();
            assert_eq!(t, 10.0);
            assert_eq!(e, 1000 + i, "tie order broke after rebuilds");
        }
        assert!(q.next().is_none());
    }

    #[test]
    fn sparse_gaps_jump_the_cursor() {
        let mut q = EventQueue::new();
        // Events many laps apart with interleaved pops: exercises the
        // fruitless-lap fallback that jumps the cursor directly.
        q.schedule(0.5, "a");
        q.schedule(1.0e6, "b");
        assert_eq!(q.next(), Some((0.5, "a")));
        q.schedule(2.0e6, "c");
        assert_eq!(q.next(), Some((1.0e6, "b")));
        assert_eq!(q.next(), Some((2.0e6, "c")));
        // The clock keeps working after the jumps.
        q.schedule_in(1.0, "d");
        assert_eq!(q.next(), Some((2.0e6 + 1.0, "d")));
    }
}
