//! Retrieval substrate — the ChromaDB substitute.
//!
//! # IVF search
//!
//! [`IvfIndex`] is an inverted-file dense vector index: passages are
//! clustered into `n_lists` lists by cosine k-means; a query scores the
//! list centroids, probes the nearest lists, and exact-scores the
//! gathered candidates. Degenerate (empty) clusters left behind by
//! k-means are repaired at build time by reseeding from the largest
//! list, so the effective list count always equals `n_lists` and the
//! probe curve stays calibrated.
//!
//! # The `search_ef` bound
//!
//! `search_ef` caps the number of candidates exact-scored per query:
//! lists are probed in decreasing centroid similarity until at least
//! `search_ef` candidates have been gathered. It is the paper's Fig. 4
//! knob (ChromaDB's `search_ef`), and the axis along which retrieval
//! trades recall for latency:
//!
//! * low `search_ef` → few lists probed → fast, but the true top-k may
//!   live in an unprobed list (recall < 1). For small K the paper
//!   measures up to ~20× speedup at modest recall loss;
//! * `search_ef >= corpus size` → every list probed → exact search.
//!
//! Because candidates are gathered in whole lists, the actual candidate
//! count quantizes to list-size granularity (always ≥ `search_ef` until
//! the corpus is exhausted).
//!
//! # Sharded search (scatter-gather)
//!
//! [`ShardedIndex`] partitions the corpus round-robin across `n_shards`
//! independent [`IvfIndex`] shards (see [`sharded`]). A query scatters to
//! every shard in parallel (scoped threads), each shard probes its slice
//! with `search_ef / n_shards` of the candidate budget, and the sorted
//! per-shard top-k lists are gathered with a binary-heap k-way merge.
//! Compared to one big index at the same total budget:
//!
//! * **latency** — per-shard work is ~1/S of the single-index search and
//!   runs concurrently, so service time approaches `t₁/S` plus a small
//!   scatter/merge overhead (calibrated in `sim::cluster`);
//! * **recall** — each shard returns its *local* top-k, so the merged
//!   candidate pool is at least as targeted as the single-index probe at
//!   the same total `search_ef`; with the full budget the result is
//!   exactly the single-index top-k (the oracle property tested in
//!   [`sharded`]);
//! * **scalability** — shards are independent replica pools, which is
//!   what lets the allocation LP and the autoscaler size retrieval
//!   separately from the LLM stages (the paper's "unique scalability
//!   characteristics").
//!
//! [`IvfIndex::search_batch`] / [`ShardedIndex::search_batch`] amortize a
//! query batch: centroid scoring runs centroid-major across the whole
//! batch, and the scatter fan-out costs one thread spawn per shard per
//! batch instead of per query.
//!
//! # Kernels, quantization, and top-k selection
//!
//! The scoring hot path (see [`store`]) is built from blocked 8-lane
//! kernels over a padded row-major layout ([`dot_f32`], autovectorizable
//! on stable Rust), an opt-in SQ8 scalar-quantized storage mode
//! ([`Quantization::SQ8`]: u8 codes + per-dim min/scale, 4× less scan
//! bandwidth, exact f32 rescoring over the top `rerank_factor × k`
//! survivors), and a bounded-heap streaming top-k ([`TopK`]) with one
//! deterministic total order (score desc, ties to the lower id, NaN via
//! `total_cmp`). The default mode is unquantized f32, which replays
//! golden traces bit-identically; `benches/perf_retrieval.rs` measures
//! all three mechanisms and gates regressions.
//!
//! Scoring runs either in pure Rust (`score_block`) or through the Pallas
//! `retrieval_score` artifact (live mode; see `runtime::scorer`).

pub mod sharded;
pub mod store;

pub use sharded::{ShardParams, ShardedIndex};
pub use store::{dot_f32, IvfIndex, IvfParams, Quantization, SearchResult, Searcher, TopK, LANES};
