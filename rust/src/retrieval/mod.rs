//! Retrieval substrate — the ChromaDB substitute.
//!
//! An IVF (inverted-file) dense vector index: passages are clustered into
//! lists by k-means; a query probes the nearest lists and exact-scores the
//! candidates. The `search_ef` knob bounds the number of candidates
//! scanned — the same latency/recall tradeoff the paper tunes in ChromaDB
//! (Fig. 4: for small K, low `search_ef` is up to ~20× faster).
//!
//! Scoring runs either in pure Rust (`score_block`) or through the Pallas
//! `retrieval_score` artifact (live mode; see `runtime::scorer`).

pub mod store;

pub use store::{IvfIndex, IvfParams, SearchResult};
