//! IVF dense-vector index with a `search_ef` candidate bound.

use crate::util::rng::Rng;

/// Index construction parameters.
#[derive(Clone, Copy, Debug)]
pub struct IvfParams {
    /// Number of inverted lists (clusters).
    pub n_lists: usize,
    /// Lloyd iterations for k-means.
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for IvfParams {
    fn default() -> Self {
        IvfParams { n_lists: 32, kmeans_iters: 8, seed: 0 }
    }
}

/// One search hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchResult {
    pub id: usize,
    pub score: f32,
}

/// Inverted-file index over unit-norm embeddings.
pub struct IvfIndex {
    dim: usize,
    /// Flattened embeddings, row-major [n, dim].
    vectors: Vec<f32>,
    /// Cluster centroids [n_lists, dim].
    centroids: Vec<f32>,
    /// Member vector ids per list.
    lists: Vec<Vec<usize>>,
}

impl IvfIndex {
    /// Build from row-major `vectors` ([n, dim]).
    pub fn build(vectors: Vec<f32>, dim: usize, params: IvfParams) -> IvfIndex {
        assert!(dim > 0 && vectors.len() % dim == 0);
        let n = vectors.len() / dim;
        assert!(n > 0);
        let n_lists = params.n_lists.min(n);
        let mut rng = Rng::new(params.seed);

        // k-means++ -lite init: random distinct rows.
        let mut idxs: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idxs);
        let mut centroids: Vec<f32> = Vec::with_capacity(n_lists * dim);
        for &i in idxs.iter().take(n_lists) {
            centroids.extend_from_slice(&vectors[i * dim..(i + 1) * dim]);
        }

        let mut assign = vec![0usize; n];
        for _ in 0..params.kmeans_iters {
            // Assign.
            for i in 0..n {
                let v = &vectors[i * dim..(i + 1) * dim];
                let mut best = (f32::NEG_INFINITY, 0usize);
                for c in 0..n_lists {
                    let s = dot(v, &centroids[c * dim..(c + 1) * dim]);
                    if s > best.0 {
                        best = (s, c);
                    }
                }
                assign[i] = best.1;
            }
            // Update (mean, renormalized — cosine k-means).
            let mut sums = vec![0f32; n_lists * dim];
            let mut counts = vec![0usize; n_lists];
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1;
                for d in 0..dim {
                    sums[c * dim + d] += vectors[i * dim + d];
                }
            }
            for c in 0..n_lists {
                if counts[c] == 0 {
                    // Re-seed empty cluster with a random row.
                    let i = rng.index(n);
                    sums[c * dim..(c + 1) * dim]
                        .copy_from_slice(&vectors[i * dim..(i + 1) * dim]);
                    counts[c] = 1;
                }
                let norm = sums[c * dim..(c + 1) * dim]
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt()
                    .max(1e-9);
                for d in 0..dim {
                    centroids[c * dim + d] = sums[c * dim + d] / norm;
                }
            }
        }
        // Final assignment into lists.
        let mut lists = vec![Vec::new(); n_lists];
        for i in 0..n {
            let v = &vectors[i * dim..(i + 1) * dim];
            let mut best = (f32::NEG_INFINITY, 0usize);
            for c in 0..n_lists {
                let s = dot(v, &centroids[c * dim..(c + 1) * dim]);
                if s > best.0 {
                    best = (s, c);
                }
            }
            lists[best.1].push(i);
        }
        repair_empty_lists(&vectors, dim, &mut centroids, &mut lists);
        IvfIndex { dim, vectors, centroids, lists }
    }

    /// List occupancy (diagnostics; after [`IvfIndex::build`] every list
    /// is non-empty as long as the corpus has at least `n_lists` rows).
    pub fn list_sizes(&self) -> Vec<usize> {
        self.lists.iter().map(|l| l.len()).collect()
    }

    pub fn len(&self) -> usize {
        self.vectors.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.vectors.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn n_lists(&self) -> usize {
        self.lists.len()
    }

    /// Candidate ids scanned for a query at a given `search_ef`: nearest
    /// lists are probed (by centroid similarity) until at least
    /// `search_ef` candidates have been gathered.
    pub fn candidates(&self, query: &[f32], search_ef: usize) -> Vec<usize> {
        assert_eq!(query.len(), self.dim);
        let scores: Vec<(f32, usize)> = (0..self.lists.len())
            .map(|c| (dot(query, &self.centroids[c * self.dim..(c + 1) * self.dim]), c))
            .collect();
        self.gather_by_scores(scores, search_ef)
    }

    /// Probe lists in decreasing `scores` order until at least `ef`
    /// candidates are gathered. Shared by [`IvfIndex::candidates`] and
    /// [`IvfIndex::search_batch`]: the probe order and tie behavior being
    /// identical is what makes batched results match `search` exactly.
    fn gather_by_scores(&self, mut scores: Vec<(f32, usize)>, ef: usize) -> Vec<usize> {
        scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut cand = Vec::with_capacity(ef + 64);
        for (_, c) in scores {
            cand.extend_from_slice(&self.lists[c]);
            if cand.len() >= ef {
                break;
            }
        }
        cand
    }

    /// Exact-score a candidate set and return the top-k.
    pub fn score_candidates(&self, query: &[f32], cand: &[usize], k: usize) -> Vec<SearchResult> {
        let mut scored: Vec<SearchResult> = cand
            .iter()
            .map(|&i| SearchResult {
                id: i,
                score: dot(query, &self.vectors[i * self.dim..(i + 1) * self.dim]),
            })
            .collect();
        // Partial select: top-k by score.
        let k = k.min(scored.len());
        scored.select_nth_unstable_by(k.saturating_sub(1), |a, b| {
            b.score.partial_cmp(&a.score).unwrap()
        });
        scored.truncate(k);
        scored.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        scored
    }

    /// Search: probe lists up to `search_ef` candidates, return top-k.
    pub fn search(&self, query: &[f32], k: usize, search_ef: usize) -> Vec<SearchResult> {
        let cand = self.candidates(query, search_ef.max(k));
        self.score_candidates(query, &cand, k)
    }

    /// Batched multi-query search. Centroid scoring runs centroid-major —
    /// one pass over the centroid block serves the whole batch, keeping
    /// each centroid row hot in cache across queries — which is where most
    /// of a small-`search_ef` probe's time goes once `n_lists` is large.
    /// Results per query are identical to [`IvfIndex::search`].
    pub fn search_batch(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        search_ef: usize,
    ) -> Vec<Vec<SearchResult>> {
        let nq = queries.len();
        let nl = self.lists.len();
        if nq == 0 {
            return Vec::new();
        }
        for q in queries {
            assert_eq!(q.len(), self.dim, "query dim mismatch");
        }
        // [nq, nl] query-centroid scores, filled centroid-major.
        let mut cscores = vec![0f32; nq * nl];
        for c in 0..nl {
            let cv = &self.centroids[c * self.dim..(c + 1) * self.dim];
            for (qi, q) in queries.iter().enumerate() {
                cscores[qi * nl + c] = dot(q, cv);
            }
        }
        let ef = search_ef.max(k);
        queries
            .iter()
            .enumerate()
            .map(|(qi, q)| {
                let scores: Vec<(f32, usize)> =
                    (0..nl).map(|c| (cscores[qi * nl + c], c)).collect();
                let cand = self.gather_by_scores(scores, ef);
                self.score_candidates(q, &cand, k)
            })
            .collect()
    }

    /// Brute-force exact top-k (ground truth for recall).
    pub fn search_exact(&self, query: &[f32], k: usize) -> Vec<SearchResult> {
        let all: Vec<usize> = (0..self.len()).collect();
        self.score_candidates(query, &all, k)
    }

    /// Recall@k of `got` against ground-truth `exact`.
    pub fn recall(got: &[SearchResult], exact: &[SearchResult]) -> f64 {
        if exact.is_empty() {
            return 1.0;
        }
        let truth: std::collections::HashSet<usize> = exact.iter().map(|r| r.id).collect();
        let hit = got.iter().filter(|r| truth.contains(&r.id)).count();
        hit as f64 / exact.len() as f64
    }

    /// Raw vector row (used by the XLA scorer path to build shards).
    pub fn vector(&self, i: usize) -> &[f32] {
        &self.vectors[i * self.dim..(i + 1) * self.dim]
    }
}

/// Repair degenerate clusters after k-means: duplicate rows or an unlucky
/// init can leave inverted lists empty, silently shrinking the effective
/// `n_lists` (a probe that "covers" such a list gathers nothing, skewing
/// the `search_ef` ↔ recall curve). Each empty list is reseeded from the
/// largest list: the donor's member *least* similar to the donor centroid
/// moves over and becomes the new centroid. Every iteration fills one
/// empty list while leaving the donor non-empty, so the loop terminates
/// with all lists occupied whenever the corpus has ≥ `n_lists` rows.
fn repair_empty_lists(
    vectors: &[f32],
    dim: usize,
    centroids: &mut [f32],
    lists: &mut [Vec<usize>],
) {
    loop {
        let Some(empty) = lists.iter().position(|l| l.is_empty()) else { break };
        let donor = (0..lists.len())
            .max_by_key(|&c| lists[c].len())
            .expect("at least one list");
        if lists[donor].len() < 2 {
            break; // corpus smaller than n_lists: nothing left to split
        }
        let dc = &centroids[donor * dim..(donor + 1) * dim];
        let (pos, _) = lists[donor]
            .iter()
            .enumerate()
            .map(|(p, &vid)| (p, dot(&vectors[vid * dim..(vid + 1) * dim], dc)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("donor non-empty");
        let vid = lists[donor].swap_remove(pos);
        lists[empty].push(vid);
        centroids[empty * dim..(empty + 1) * dim]
            .copy_from_slice(&vectors[vid * dim..(vid + 1) * dim]);
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f32;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::workload::corpus::Corpus;

    fn build_test_index(n: usize, dim: usize, seed: u64) -> (IvfIndex, Corpus) {
        let corpus = Corpus::generate(n, 8, 64, seed);
        let mut vectors = Vec::with_capacity(n * dim);
        for p in &corpus.passages {
            vectors.extend(Corpus::hash_embed(&p.text, dim));
        }
        (IvfIndex::build(vectors, dim, IvfParams::default()), corpus)
    }

    #[test]
    fn exact_search_finds_self() {
        let (idx, _) = build_test_index(500, 32, 0);
        for i in [0usize, 100, 499] {
            let q: Vec<f32> = idx.vector(i).to_vec();
            let top = idx.search_exact(&q, 1);
            assert_eq!(top[0].id, i);
        }
    }

    #[test]
    fn higher_ef_higher_recall() {
        let (idx, corpus) = build_test_index(2000, 32, 1);
        let mut qg = crate::workload::queries::QueryGen::new(&corpus, 3);
        let k = 10;
        let mut recalls = Vec::new();
        for ef in [20usize, 200, 2000] {
            let mut total = 0.0;
            let trials = 20;
            for _ in 0..trials {
                let q = qg.next();
                let qe = Corpus::hash_embed(&q.text, 32);
                let got = idx.search(&qe, k, ef);
                let exact = idx.search_exact(&qe, k);
                total += IvfIndex::recall(&got, &exact);
            }
            recalls.push(total / trials as f64);
        }
        assert!(recalls[0] <= recalls[1] + 0.05, "{recalls:?}");
        assert!(recalls[1] <= recalls[2] + 0.05, "{recalls:?}");
        // Full-ef scan must be exact.
        assert!(recalls[2] > 0.999, "{recalls:?}");
    }

    #[test]
    fn candidates_bounded_by_ef_granularity() {
        let (idx, _) = build_test_index(1000, 32, 2);
        let q = idx.vector(0).to_vec();
        let c_small = idx.candidates(&q, 10);
        let c_large = idx.candidates(&q, 1000);
        assert!(c_small.len() < c_large.len());
        assert_eq!(c_large.len(), 1000, "full probe covers corpus");
    }

    #[test]
    fn search_results_sorted_and_k_bounded() {
        let (idx, _) = build_test_index(300, 16, 3);
        let q = idx.vector(5).to_vec();
        let res = idx.search(&q, 7, 100);
        assert_eq!(res.len(), 7);
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn lists_partition_the_corpus() {
        let (idx, _) = build_test_index(400, 16, 4);
        let mut seen = vec![false; idx.len()];
        for l in &idx.lists {
            for &i in l {
                assert!(!seen[i], "duplicate membership {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn search_batch_matches_single_query_search() {
        let (idx, corpus) = build_test_index(1500, 32, 8);
        let mut qg = crate::workload::queries::QueryGen::new(&corpus, 5);
        let queries: Vec<Vec<f32>> =
            (0..10).map(|_| Corpus::hash_embed(&qg.next().text, 32)).collect();
        for ef in [30usize, 300, 1500] {
            let batched = idx.search_batch(&queries, 8, ef);
            assert_eq!(batched.len(), queries.len());
            for (q, got) in queries.iter().zip(&batched) {
                let want = idx.search(q, 8, ef);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.id, b.id);
                    assert_eq!(a.score, b.score);
                }
            }
        }
    }

    #[test]
    fn degenerate_clusters_are_repaired() {
        // All rows identical: k-means collapses every row into one list,
        // which without repair leaves n_lists - 1 lists empty.
        let dim = 16;
        let n = 64;
        let one = Corpus::hash_embed(b"the same passage", dim);
        let mut vectors = Vec::with_capacity(n * dim);
        for _ in 0..n {
            vectors.extend_from_slice(&one);
        }
        let idx = IvfIndex::build(
            vectors,
            dim,
            IvfParams { n_lists: 8, kmeans_iters: 4, seed: 3 },
        );
        let sizes = idx.list_sizes();
        assert_eq!(sizes.len(), 8);
        assert!(sizes.iter().all(|&s| s > 0), "empty list survived repair: {sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), n, "repair must preserve the partition");
    }

    #[test]
    fn repaired_lists_still_partition_clustered_corpus() {
        // A corpus with fewer distinct rows than lists exercises the
        // donor loop repeatedly.
        let dim = 16;
        let a = Corpus::hash_embed(b"topic alpha", dim);
        let b = Corpus::hash_embed(b"topic beta", dim);
        let mut vectors = Vec::new();
        for i in 0..40 {
            vectors.extend_from_slice(if i % 2 == 0 { &a } else { &b });
        }
        let idx = IvfIndex::build(
            vectors,
            dim,
            IvfParams { n_lists: 10, kmeans_iters: 6, seed: 9 },
        );
        let sizes = idx.list_sizes();
        assert!(sizes.iter().all(|&s| s > 0), "{sizes:?}");
        let mut seen = vec![false; idx.len()];
        for l in &idx.lists {
            for &i in l {
                assert!(!seen[i], "duplicate membership {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn recall_metric_sane() {
        let a = [SearchResult { id: 1, score: 1.0 }, SearchResult { id: 2, score: 0.9 }];
        let b = [SearchResult { id: 1, score: 1.0 }, SearchResult { id: 3, score: 0.8 }];
        assert_eq!(IvfIndex::recall(&a, &b), 0.5);
        assert_eq!(IvfIndex::recall(&a, &a), 1.0);
        assert_eq!(IvfIndex::recall(&[], &[]), 1.0);
    }

    #[test]
    fn search_property_topk_dominates_rest() {
        property("ivf top-k dominance", 10, |g| {
            let n = g.usize(100, 400);
            let (idx, _) = build_test_index(n, 16, g.i64(0, 1 << 20) as u64);
            let qi = g.usize(0, n - 1);
            let q = idx.vector(qi).to_vec();
            let k = g.usize(1, 10);
            let res = idx.search_exact(&q, k);
            // every returned score >= any non-returned score
            let min_ret = res.last().unwrap().score;
            let ids: std::collections::HashSet<usize> = res.iter().map(|r| r.id).collect();
            for i in 0..n {
                if !ids.contains(&i) {
                    let s: f32 = idx
                        .vector(i)
                        .iter()
                        .zip(&q)
                        .map(|(a, b)| a * b)
                        .sum();
                    assert!(s <= min_ret + 1e-5);
                }
            }
        });
    }
}
