//! IVF dense-vector index with a `search_ef` candidate bound.

use crate::util::rng::Rng;

/// Index construction parameters.
#[derive(Clone, Copy, Debug)]
pub struct IvfParams {
    /// Number of inverted lists (clusters).
    pub n_lists: usize,
    /// Lloyd iterations for k-means.
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for IvfParams {
    fn default() -> Self {
        IvfParams { n_lists: 32, kmeans_iters: 8, seed: 0 }
    }
}

/// One search hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchResult {
    pub id: usize,
    pub score: f32,
}

/// Inverted-file index over unit-norm embeddings.
pub struct IvfIndex {
    dim: usize,
    /// Flattened embeddings, row-major [n, dim].
    vectors: Vec<f32>,
    /// Cluster centroids [n_lists, dim].
    centroids: Vec<f32>,
    /// Member vector ids per list.
    lists: Vec<Vec<usize>>,
}

impl IvfIndex {
    /// Build from row-major `vectors` ([n, dim]).
    pub fn build(vectors: Vec<f32>, dim: usize, params: IvfParams) -> IvfIndex {
        assert!(dim > 0 && vectors.len() % dim == 0);
        let n = vectors.len() / dim;
        assert!(n > 0);
        let n_lists = params.n_lists.min(n);
        let mut rng = Rng::new(params.seed);

        // k-means++ -lite init: random distinct rows.
        let mut idxs: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idxs);
        let mut centroids: Vec<f32> = Vec::with_capacity(n_lists * dim);
        for &i in idxs.iter().take(n_lists) {
            centroids.extend_from_slice(&vectors[i * dim..(i + 1) * dim]);
        }

        let mut assign = vec![0usize; n];
        for _ in 0..params.kmeans_iters {
            // Assign.
            for i in 0..n {
                let v = &vectors[i * dim..(i + 1) * dim];
                let mut best = (f32::NEG_INFINITY, 0usize);
                for c in 0..n_lists {
                    let s = dot(v, &centroids[c * dim..(c + 1) * dim]);
                    if s > best.0 {
                        best = (s, c);
                    }
                }
                assign[i] = best.1;
            }
            // Update (mean, renormalized — cosine k-means).
            let mut sums = vec![0f32; n_lists * dim];
            let mut counts = vec![0usize; n_lists];
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1;
                for d in 0..dim {
                    sums[c * dim + d] += vectors[i * dim + d];
                }
            }
            for c in 0..n_lists {
                if counts[c] == 0 {
                    // Re-seed empty cluster with a random row.
                    let i = rng.index(n);
                    sums[c * dim..(c + 1) * dim]
                        .copy_from_slice(&vectors[i * dim..(i + 1) * dim]);
                    counts[c] = 1;
                }
                let norm = sums[c * dim..(c + 1) * dim]
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt()
                    .max(1e-9);
                for d in 0..dim {
                    centroids[c * dim + d] = sums[c * dim + d] / norm;
                }
            }
        }
        // Final assignment into lists.
        let mut lists = vec![Vec::new(); n_lists];
        for i in 0..n {
            let v = &vectors[i * dim..(i + 1) * dim];
            let mut best = (f32::NEG_INFINITY, 0usize);
            for c in 0..n_lists {
                let s = dot(v, &centroids[c * dim..(c + 1) * dim]);
                if s > best.0 {
                    best = (s, c);
                }
            }
            lists[best.1].push(i);
        }
        IvfIndex { dim, vectors, centroids, lists }
    }

    pub fn len(&self) -> usize {
        self.vectors.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.vectors.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn n_lists(&self) -> usize {
        self.lists.len()
    }

    /// Candidate ids scanned for a query at a given `search_ef`: nearest
    /// lists are probed (by centroid similarity) until at least
    /// `search_ef` candidates have been gathered.
    pub fn candidates(&self, query: &[f32], search_ef: usize) -> Vec<usize> {
        assert_eq!(query.len(), self.dim);
        let mut order: Vec<(f32, usize)> = (0..self.lists.len())
            .map(|c| (dot(query, &self.centroids[c * self.dim..(c + 1) * self.dim]), c))
            .collect();
        order.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut cand = Vec::with_capacity(search_ef + 64);
        for (_, c) in order {
            cand.extend_from_slice(&self.lists[c]);
            if cand.len() >= search_ef {
                break;
            }
        }
        cand
    }

    /// Exact-score a candidate set and return the top-k.
    pub fn score_candidates(&self, query: &[f32], cand: &[usize], k: usize) -> Vec<SearchResult> {
        let mut scored: Vec<SearchResult> = cand
            .iter()
            .map(|&i| SearchResult {
                id: i,
                score: dot(query, &self.vectors[i * self.dim..(i + 1) * self.dim]),
            })
            .collect();
        // Partial select: top-k by score.
        let k = k.min(scored.len());
        scored.select_nth_unstable_by(k.saturating_sub(1), |a, b| {
            b.score.partial_cmp(&a.score).unwrap()
        });
        scored.truncate(k);
        scored.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        scored
    }

    /// Search: probe lists up to `search_ef` candidates, return top-k.
    pub fn search(&self, query: &[f32], k: usize, search_ef: usize) -> Vec<SearchResult> {
        let cand = self.candidates(query, search_ef.max(k));
        self.score_candidates(query, &cand, k)
    }

    /// Brute-force exact top-k (ground truth for recall).
    pub fn search_exact(&self, query: &[f32], k: usize) -> Vec<SearchResult> {
        let all: Vec<usize> = (0..self.len()).collect();
        self.score_candidates(query, &all, k)
    }

    /// Recall@k of `got` against ground-truth `exact`.
    pub fn recall(got: &[SearchResult], exact: &[SearchResult]) -> f64 {
        if exact.is_empty() {
            return 1.0;
        }
        let truth: std::collections::HashSet<usize> = exact.iter().map(|r| r.id).collect();
        let hit = got.iter().filter(|r| truth.contains(&r.id)).count();
        hit as f64 / exact.len() as f64
    }

    /// Raw vector row (used by the XLA scorer path to build shards).
    pub fn vector(&self, i: usize) -> &[f32] {
        &self.vectors[i * self.dim..(i + 1) * self.dim]
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f32;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::workload::corpus::Corpus;

    fn build_test_index(n: usize, dim: usize, seed: u64) -> (IvfIndex, Corpus) {
        let corpus = Corpus::generate(n, 8, 64, seed);
        let mut vectors = Vec::with_capacity(n * dim);
        for p in &corpus.passages {
            vectors.extend(Corpus::hash_embed(&p.text, dim));
        }
        (IvfIndex::build(vectors, dim, IvfParams::default()), corpus)
    }

    #[test]
    fn exact_search_finds_self() {
        let (idx, _) = build_test_index(500, 32, 0);
        for i in [0usize, 100, 499] {
            let q: Vec<f32> = idx.vector(i).to_vec();
            let top = idx.search_exact(&q, 1);
            assert_eq!(top[0].id, i);
        }
    }

    #[test]
    fn higher_ef_higher_recall() {
        let (idx, corpus) = build_test_index(2000, 32, 1);
        let mut qg = crate::workload::queries::QueryGen::new(&corpus, 3);
        let k = 10;
        let mut recalls = Vec::new();
        for ef in [20usize, 200, 2000] {
            let mut total = 0.0;
            let trials = 20;
            for _ in 0..trials {
                let q = qg.next();
                let qe = Corpus::hash_embed(&q.text, 32);
                let got = idx.search(&qe, k, ef);
                let exact = idx.search_exact(&qe, k);
                total += IvfIndex::recall(&got, &exact);
            }
            recalls.push(total / trials as f64);
        }
        assert!(recalls[0] <= recalls[1] + 0.05, "{recalls:?}");
        assert!(recalls[1] <= recalls[2] + 0.05, "{recalls:?}");
        // Full-ef scan must be exact.
        assert!(recalls[2] > 0.999, "{recalls:?}");
    }

    #[test]
    fn candidates_bounded_by_ef_granularity() {
        let (idx, _) = build_test_index(1000, 32, 2);
        let q = idx.vector(0).to_vec();
        let c_small = idx.candidates(&q, 10);
        let c_large = idx.candidates(&q, 1000);
        assert!(c_small.len() < c_large.len());
        assert_eq!(c_large.len(), 1000, "full probe covers corpus");
    }

    #[test]
    fn search_results_sorted_and_k_bounded() {
        let (idx, _) = build_test_index(300, 16, 3);
        let q = idx.vector(5).to_vec();
        let res = idx.search(&q, 7, 100);
        assert_eq!(res.len(), 7);
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn lists_partition_the_corpus() {
        let (idx, _) = build_test_index(400, 16, 4);
        let mut seen = vec![false; idx.len()];
        for l in &idx.lists {
            for &i in l {
                assert!(!seen[i], "duplicate membership {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn recall_metric_sane() {
        let a = [SearchResult { id: 1, score: 1.0 }, SearchResult { id: 2, score: 0.9 }];
        let b = [SearchResult { id: 1, score: 1.0 }, SearchResult { id: 3, score: 0.8 }];
        assert_eq!(IvfIndex::recall(&a, &b), 0.5);
        assert_eq!(IvfIndex::recall(&a, &a), 1.0);
        assert_eq!(IvfIndex::recall(&[], &[]), 1.0);
    }

    #[test]
    fn search_property_topk_dominates_rest() {
        property("ivf top-k dominance", 10, |g| {
            let n = g.usize(100, 400);
            let (idx, _) = build_test_index(n, 16, g.i64(0, 1 << 20) as u64);
            let qi = g.usize(0, n - 1);
            let q = idx.vector(qi).to_vec();
            let k = g.usize(1, 10);
            let res = idx.search_exact(&q, k);
            // every returned score >= any non-returned score
            let min_ret = res.last().unwrap().score;
            let ids: std::collections::HashSet<usize> = res.iter().map(|r| r.id).collect();
            for i in 0..n {
                if !ids.contains(&i) {
                    let s: f32 = idx
                        .vector(i)
                        .iter()
                        .zip(&q)
                        .map(|(a, b)| a * b)
                        .sum();
                    assert!(s <= min_ret + 1e-5);
                }
            }
        });
    }
}
