//! IVF dense-vector index with a `search_ef` candidate bound, blocked
//! autovectorizable scoring kernels, optional SQ8 scalar quantization
//! with exact rescoring, and bounded-heap top-k selection.
//!
//! # Kernel shape
//!
//! Rows (vectors, centroids, and SQ8 code rows) are stored row-major in
//! one flat allocation, padded to a [`LANES`]-multiple `stride` with
//! zeros, so every inner scoring loop runs whole 8-lane blocks with
//! eight independent accumulators ([`dot_f32`], `dot_sq8`) — the shape
//! LLVM autovectorizes on stable Rust without intrinsics or
//! `target-feature` gymnastics (`benches/perf_retrieval.rs` is the
//! proof-by-measurement). The zero tail contributes nothing to a dot
//! product, and because *both* operands are padded the summation order
//! is identical everywhere a score is computed, which is what keeps
//! [`IvfIndex::search_batch`] bit-identical to [`IvfIndex::search`].
//!
//! # Top-k selection
//!
//! Scoring streams candidates through a fixed-capacity bounded heap
//! ([`TopK`]) instead of materializing a candidate-id `Vec`, scoring it
//! wholesale, and `select_nth`-ing the survivors. The heap keeps the
//! best `k` seen so far with the weakest at the root (O(n log k), no
//! allocation beyond the k-slot buffer), under one deterministic total
//! order — score descending, ties to the lower id, NaN handled by
//! `f32::total_cmp` — so results carry an exact, reproducible tie order.
//!
//! # SQ8 scalar quantization (opt-in)
//!
//! [`Quantization::SQ8`] stores per-dimension `min`/`scale` plus one u8
//! code per dimension (4× less scan bandwidth than f32). Scoring is
//! asymmetric — the query stays f32 — via the identity
//!
//! `dot(q, deq(row)) = dot(q, min) + Σ_d (q_d·scale_d)·code_d`
//!
//! with `q_d·scale_d` precomputed once per query, so the scan kernel is
//! a u8→f32 widen + multiply-accumulate. The quantized scan selects
//! `rerank_factor × k` survivors which an exact f32 **rescoring pass**
//! re-ranks; returned ids/scores are therefore exact dot products, and
//! recall@k stays within a pinned band of the unquantized index (the
//! property suite enforces ≥ f32 recall − 0.02).

use crate::util::rng::Rng;

/// Lane width of the blocked kernels: 8 × f32 = one AVX2 register, two
/// NEON registers. Row storage pads every row to a multiple of this.
pub const LANES: usize = 8;

/// Storage/scoring mode for the scanned vectors. The default is
/// unquantized f32 — existing indexes, golden traces, and the sharded
/// oracle tests are bit-identical to the pre-quantization code.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Quantization {
    /// Full-precision f32 scan (exact scoring, the default).
    #[default]
    None,
    /// Scalar-quantized u8 scan (per-dim min/scale) with an exact f32
    /// rescoring pass over the top `rerank_factor × k` survivors.
    SQ8,
}

/// Index construction parameters.
#[derive(Clone, Copy, Debug)]
pub struct IvfParams {
    /// Number of inverted lists (clusters).
    pub n_lists: usize,
    /// Lloyd iterations for k-means.
    pub kmeans_iters: usize,
    pub seed: u64,
    /// Vector storage/scoring mode (see [`Quantization`]).
    pub quantization: Quantization,
    /// SQ8 shortlist width: the quantized scan keeps `rerank_factor × k`
    /// survivors for the exact rescoring pass. Ignored under
    /// [`Quantization::None`]. Clamped to ≥ 1.
    pub rerank_factor: usize,
}

impl Default for IvfParams {
    fn default() -> Self {
        IvfParams {
            n_lists: 32,
            kmeans_iters: 8,
            seed: 0,
            quantization: Quantization::None,
            rerank_factor: 4,
        }
    }
}

/// One search hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchResult {
    pub id: usize,
    pub score: f32,
}

// ---------------------------------------------------------------------------
// Blocked kernels
// ---------------------------------------------------------------------------

/// Fold the eight lane accumulators in a fixed tree order (deterministic
/// regardless of how the loop above was vectorized).
#[inline]
fn fold(acc: [f32; LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Blocked dot product: 8-lane unrolled with independent accumulators,
/// scalar tail for non-multiple lengths. On the index's padded rows the
/// tail is empty, so every score in the index is one summation shape —
/// bit-identical across `search`, `search_batch`, `search_exact`, and
/// `score_candidates`.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let mut acc = [0f32; LANES];
    for (xa, xb) in ca.zip(cb) {
        for l in 0..LANES {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut tail = 0f32;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    fold(acc) + tail
}

/// Asymmetric SQ8 kernel: `Σ_d qscaled[d] · codes[d]` where
/// `qscaled[d] = q_d · scale_d` was precomputed per query. The u8→f32
/// widen + multiply-accumulate vectorizes on stable Rust; callers pass
/// whole padded rows (zero-padded tails contribute nothing).
#[inline]
fn dot_sq8(qscaled: &[f32], codes: &[u8]) -> f32 {
    debug_assert_eq!(qscaled.len(), codes.len());
    debug_assert_eq!(qscaled.len() % LANES, 0);
    let mut acc = [0f32; LANES];
    for (xq, xc) in qscaled.chunks_exact(LANES).zip(codes.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += xq[l] * xc[l] as f32;
        }
    }
    fold(acc)
}

// ---------------------------------------------------------------------------
// Bounded-heap top-k
// ---------------------------------------------------------------------------

/// Fixed-capacity top-k selector: a k-slot binary heap holding the best
/// `k` candidates streamed so far, weakest at the root, so a stream of
/// `n` candidates selects its top-k in O(n log k) with no allocation
/// beyond the k-slot buffer. One deterministic total order everywhere:
/// higher score wins, score ties go to the lower id, and NaN is ordered
/// by `f32::total_cmp` (above +∞) — a NaN score can therefore displace
/// results but can never panic or scramble the heap invariant.
pub struct TopK {
    k: usize,
    heap: Vec<SearchResult>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        TopK { k, heap: Vec::with_capacity(k) }
    }

    /// `a` ranks strictly above `b`: higher score, ties to the lower id.
    #[inline]
    fn beats(a: &SearchResult, b: &SearchResult) -> bool {
        match a.score.total_cmp(&b.score) {
            std::cmp::Ordering::Greater => true,
            std::cmp::Ordering::Less => false,
            std::cmp::Ordering::Equal => a.id < b.id,
        }
    }

    /// Offer one candidate. O(log k) worst case, O(1) when the heap is
    /// full and the candidate loses to the current weakest (the common
    /// case once the heap warms up).
    #[inline]
    pub fn push(&mut self, id: usize, score: f32) {
        if self.k == 0 {
            return;
        }
        let cand = SearchResult { id, score };
        if self.heap.len() < self.k {
            self.heap.push(cand);
            self.sift_up(self.heap.len() - 1);
        } else if Self::beats(&cand, &self.heap[0]) {
            self.heap[0] = cand;
            self.sift_down();
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            // Weakest-at-root: a parent that beats its child sits too low.
            if Self::beats(&self.heap[p], &self.heap[i]) {
                self.heap.swap(p, i);
                i = p;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self) {
        let n = self.heap.len();
        let mut i = 0;
        loop {
            let l = 2 * i + 1;
            if l >= n {
                break;
            }
            let r = l + 1;
            // Descend toward the weaker child.
            let weak = if r < n && Self::beats(&self.heap[l], &self.heap[r]) { r } else { l };
            if Self::beats(&self.heap[i], &self.heap[weak]) {
                self.heap.swap(i, weak);
                i = weak;
            } else {
                break;
            }
        }
    }

    /// Finish: the kept candidates in final order (score descending,
    /// ties by ascending id — the same total order `push` selected by).
    pub fn into_sorted(mut self) -> Vec<SearchResult> {
        self.heap
            .sort_unstable_by(|a, b| b.score.total_cmp(&a.score).then_with(|| a.id.cmp(&b.id)));
        self.heap
    }
}

// ---------------------------------------------------------------------------
// SQ8 storage
// ---------------------------------------------------------------------------

/// Per-dimension scalar-quantized codes: `deq(i, d) = min[d] +
/// scale[d]·code[i][d]`, with `code ∈ [0, 255]` spanning the corpus
/// min..max of that dimension (round-trip error ≤ scale/2 per dim).
struct Sq8Codes {
    /// Per-dim minima, zero-padded to `stride` (the pad contributes
    /// nothing to the `dot(q, min)` offset term).
    mins: Vec<f32>,
    /// Per-dim quantization step, zero-padded to `stride`.
    scales: Vec<f32>,
    /// Row-major `[n, stride]` u8 codes, zero-padded tails.
    codes: Vec<u8>,
}

impl Sq8Codes {
    /// Quantize padded row-major `[n, stride]` vectors (corpus min/max
    /// per dimension define the grid).
    fn build(padded: &[f32], n: usize, dim: usize, stride: usize) -> Sq8Codes {
        let mut mins = vec![0f32; stride];
        let mut maxs = vec![0f32; stride];
        mins[..dim].fill(f32::INFINITY);
        maxs[..dim].fill(f32::NEG_INFINITY);
        for i in 0..n {
            let row = &padded[i * stride..i * stride + dim];
            for (d, &v) in row.iter().enumerate() {
                mins[d] = mins[d].min(v);
                maxs[d] = maxs[d].max(v);
            }
        }
        let mut scales = vec![0f32; stride];
        for d in 0..dim {
            let span = maxs[d] - mins[d];
            // A constant dimension gets scale 0: every code is 0 and
            // dequantizes exactly to the constant (min).
            scales[d] = if span > 0.0 { span / 255.0 } else { 0.0 };
        }
        let mut codes = vec![0u8; n * stride];
        for i in 0..n {
            for d in 0..dim {
                let v = padded[i * stride + d];
                let s = scales[d];
                if s > 0.0 {
                    // Saturating float→int cast: clamps to [0, 255].
                    codes[i * stride + d] = ((v - mins[d]) / s).round() as u8;
                }
            }
        }
        Sq8Codes { mins, scales, codes }
    }

    #[inline]
    fn row(&self, i: usize, stride: usize) -> &[u8] {
        &self.codes[i * stride..(i + 1) * stride]
    }

    /// Dequantized value of row `i`, dimension `d` (tests/diagnostics).
    fn dequant(&self, i: usize, d: usize, stride: usize) -> f32 {
        self.mins[d] + self.scales[d] * self.codes[i * stride + d] as f32
    }
}

// ---------------------------------------------------------------------------
// The index
// ---------------------------------------------------------------------------

/// Inverted-file index over unit-norm embeddings.
pub struct IvfIndex {
    dim: usize,
    /// Padded row width (`dim` rounded up to a [`LANES`] multiple); all
    /// row-major blocks below use this stride.
    stride: usize,
    /// Flattened embeddings, row-major `[n, stride]`, zero-padded tails.
    vectors: Vec<f32>,
    /// Cluster centroids `[n_lists, stride]`, zero-padded tails.
    centroids: Vec<f32>,
    /// Member vector ids per list.
    lists: Vec<Vec<usize>>,
    /// SQ8 codes when built with [`Quantization::SQ8`].
    sq8: Option<Sq8Codes>,
    /// Shortlist width multiplier for the SQ8 rescoring pass.
    rerank_factor: usize,
}

impl IvfIndex {
    /// Build from row-major `vectors` ([n, dim]).
    pub fn build(vectors: Vec<f32>, dim: usize, params: IvfParams) -> IvfIndex {
        assert!(dim > 0 && vectors.len() % dim == 0);
        let n = vectors.len() / dim;
        assert!(n > 0);
        let stride = dim.div_ceil(LANES) * LANES;

        // Pad rows out to the blocked stride (zero tails are inert in
        // every dot product below).
        let mut padded = vec![0f32; n * stride];
        for i in 0..n {
            padded[i * stride..i * stride + dim].copy_from_slice(&vectors[i * dim..(i + 1) * dim]);
        }
        drop(vectors);

        let n_lists = params.n_lists.min(n);
        let mut rng = Rng::new(params.seed);

        // k-means++ -lite init: random distinct rows.
        let mut idxs: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idxs);
        let mut centroids: Vec<f32> = vec![0f32; n_lists * stride];
        for (c, &i) in idxs.iter().take(n_lists).enumerate() {
            centroids[c * stride..(c + 1) * stride]
                .copy_from_slice(&padded[i * stride..(i + 1) * stride]);
        }

        let mut assign = vec![0usize; n];
        for _ in 0..params.kmeans_iters {
            // Assign.
            for i in 0..n {
                let v = &padded[i * stride..(i + 1) * stride];
                let mut best = (f32::NEG_INFINITY, 0usize);
                for c in 0..n_lists {
                    let s = dot_f32(v, &centroids[c * stride..(c + 1) * stride]);
                    if s > best.0 {
                        best = (s, c);
                    }
                }
                assign[i] = best.1;
            }
            // Update (mean, renormalized — cosine k-means).
            let mut sums = vec![0f32; n_lists * stride];
            let mut counts = vec![0usize; n_lists];
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1;
                for d in 0..dim {
                    sums[c * stride + d] += padded[i * stride + d];
                }
            }
            for c in 0..n_lists {
                if counts[c] == 0 {
                    // Re-seed empty cluster with a random row.
                    let i = rng.index(n);
                    sums[c * stride..(c + 1) * stride]
                        .copy_from_slice(&padded[i * stride..(i + 1) * stride]);
                    counts[c] = 1;
                }
                let norm = dot_f32(
                    &sums[c * stride..(c + 1) * stride],
                    &sums[c * stride..(c + 1) * stride],
                )
                .sqrt()
                .max(1e-9);
                for d in 0..dim {
                    centroids[c * stride + d] = sums[c * stride + d] / norm;
                }
            }
        }
        // Final assignment into lists.
        let mut lists = vec![Vec::new(); n_lists];
        for i in 0..n {
            let v = &padded[i * stride..(i + 1) * stride];
            let mut best = (f32::NEG_INFINITY, 0usize);
            for c in 0..n_lists {
                let s = dot_f32(v, &centroids[c * stride..(c + 1) * stride]);
                if s > best.0 {
                    best = (s, c);
                }
            }
            lists[best.1].push(i);
        }
        repair_empty_lists(&padded, stride, &mut centroids, &mut lists);

        let sq8 = match params.quantization {
            Quantization::None => None,
            Quantization::SQ8 => Some(Sq8Codes::build(&padded, n, dim, stride)),
        };
        IvfIndex {
            dim,
            stride,
            vectors: padded,
            centroids,
            lists,
            sq8,
            rerank_factor: params.rerank_factor.max(1),
        }
    }

    /// List occupancy (diagnostics; after [`IvfIndex::build`] every list
    /// is non-empty as long as the corpus has at least `n_lists` rows).
    pub fn list_sizes(&self) -> Vec<usize> {
        self.lists.iter().map(|l| l.len()).collect()
    }

    pub fn len(&self) -> usize {
        self.vectors.len() / self.stride
    }

    pub fn is_empty(&self) -> bool {
        self.vectors.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn n_lists(&self) -> usize {
        self.lists.len()
    }

    /// The storage mode this index was built with.
    pub fn quantization(&self) -> Quantization {
        if self.sq8.is_some() {
            Quantization::SQ8
        } else {
            Quantization::None
        }
    }

    /// Bytes streamed per scanned vector by the candidate scan (the
    /// bandwidth the SQ8 mode quarters).
    pub fn scan_bytes_per_vector(&self) -> usize {
        match self.sq8 {
            Some(_) => self.stride,
            None => self.stride * std::mem::size_of::<f32>(),
        }
    }

    /// Padded row (internal scoring path).
    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        &self.vectors[i * self.stride..(i + 1) * self.stride]
    }

    #[inline]
    fn centroid_row(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.stride..(c + 1) * self.stride]
    }

    /// A reusable searcher holding this index's per-query scratch
    /// (centroid scores, padded query, SQ8 query×scale products) so a
    /// batch of queries allocates once, not per query.
    pub fn searcher(&self) -> Searcher<'_> {
        Searcher {
            index: self,
            cscores: Vec::with_capacity(self.lists.len()),
            qbuf: vec![0f32; self.stride],
            qscaled: match self.sq8 {
                Some(_) => vec![0f32; self.stride],
                None => Vec::new(),
            },
        }
    }

    /// Candidate ids scanned for a query at a given `search_ef`: nearest
    /// lists are probed (by centroid similarity) until at least
    /// `search_ef` candidates have been gathered. Diagnostic API — the
    /// search path streams list slices through the bounded heap and
    /// never materializes this vector.
    pub fn candidates(&self, query: &[f32], search_ef: usize) -> Vec<usize> {
        let mut s = self.searcher();
        s.load_query(query);
        s.score_centroids();
        s.sort_probe_order();
        let n_probe = s.probe_prefix(search_ef);
        let total: usize = s.cscores[..n_probe].iter().map(|&(_, c)| self.lists[c].len()).sum();
        let mut cand = Vec::with_capacity(total);
        for &(_, c) in &s.cscores[..n_probe] {
            cand.extend_from_slice(&self.lists[c]);
        }
        cand
    }

    /// Exact-score a candidate set and return the top-k (always full
    /// f32 scoring — this is also the SQ8 rescoring primitive).
    pub fn score_candidates(&self, query: &[f32], cand: &[usize], k: usize) -> Vec<SearchResult> {
        let mut s = self.searcher();
        s.load_query(query);
        let mut top = TopK::new(k.min(cand.len()));
        for &i in cand {
            top.push(i, dot_f32(&s.qbuf, self.row(i)));
        }
        top.into_sorted()
    }

    /// Search: probe lists up to `search_ef` candidates, return top-k.
    pub fn search(&self, query: &[f32], k: usize, search_ef: usize) -> Vec<SearchResult> {
        self.searcher().search(query, k, search_ef)
    }

    /// Batched multi-query search. Centroid scoring runs centroid-major —
    /// one pass over the centroid block serves the whole batch, keeping
    /// each centroid row hot in cache across queries — which is where most
    /// of a small-`search_ef` probe's time goes once `n_lists` is large.
    /// One [`Searcher`]'s scratch serves the whole batch. Results per
    /// query are identical to [`IvfIndex::search`] (same padded-row
    /// kernels, same probe order, same bounded-heap tie order).
    pub fn search_batch(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        search_ef: usize,
    ) -> Vec<Vec<SearchResult>> {
        let nq = queries.len();
        let nl = self.lists.len();
        if nq == 0 {
            return Vec::new();
        }
        for q in queries {
            assert_eq!(q.len(), self.dim, "query dim mismatch");
        }
        // Pad the whole batch once so the centroid-major pass and the
        // per-query scans share the single-query summation shape.
        let mut qpad = vec![0f32; nq * self.stride];
        for (qi, q) in queries.iter().enumerate() {
            qpad[qi * self.stride..qi * self.stride + self.dim].copy_from_slice(q);
        }
        // [nq, nl] query-centroid scores, filled centroid-major.
        let mut cscores = vec![0f32; nq * nl];
        for c in 0..nl {
            let cv = self.centroid_row(c);
            for qi in 0..nq {
                cscores[qi * nl + c] =
                    dot_f32(&qpad[qi * self.stride..(qi + 1) * self.stride], cv);
            }
        }
        let mut s = self.searcher();
        (0..nq)
            .map(|qi| {
                s.qbuf.copy_from_slice(&qpad[qi * self.stride..(qi + 1) * self.stride]);
                s.cscores.clear();
                s.cscores.extend((0..nl).map(|c| (cscores[qi * nl + c], c)));
                s.sort_probe_order();
                s.scan(k, search_ef.max(k))
            })
            .collect()
    }

    /// Brute-force exact top-k (ground truth for recall): streams every
    /// row through the bounded heap — no candidate-id materialization,
    /// and always full-precision f32 regardless of the index's storage
    /// mode.
    pub fn search_exact(&self, query: &[f32], k: usize) -> Vec<SearchResult> {
        assert_eq!(query.len(), self.dim);
        let mut qbuf = vec![0f32; self.stride];
        qbuf[..self.dim].copy_from_slice(query);
        let mut top = TopK::new(k.min(self.len()));
        for i in 0..self.len() {
            top.push(i, dot_f32(&qbuf, self.row(i)));
        }
        top.into_sorted()
    }

    /// Recall@k of `got` against ground-truth `exact`.
    pub fn recall(got: &[SearchResult], exact: &[SearchResult]) -> f64 {
        if exact.is_empty() {
            return 1.0;
        }
        let truth: std::collections::HashSet<usize> = exact.iter().map(|r| r.id).collect();
        let hit = got.iter().filter(|r| truth.contains(&r.id)).count();
        hit as f64 / exact.len() as f64
    }

    /// Raw vector row, unpadded (used by the XLA scorer path to build
    /// shards).
    pub fn vector(&self, i: usize) -> &[f32] {
        &self.vectors[i * self.stride..i * self.stride + self.dim]
    }
}

// ---------------------------------------------------------------------------
// Searcher: per-query scratch + the scan loops
// ---------------------------------------------------------------------------

/// Reusable search state bound to one [`IvfIndex`]: the centroid-score
/// scratch, the padded query buffer, and the SQ8 query×scale products
/// live here so repeated queries (and whole batches) stop allocating a
/// `Vec<(f32, usize)>` per query.
pub struct Searcher<'a> {
    index: &'a IvfIndex,
    /// (centroid score, list id) probe scratch, sorted descending.
    cscores: Vec<(f32, usize)>,
    /// Query padded to the index stride.
    qbuf: Vec<f32>,
    /// SQ8 only: `q_d · scale_d` per dimension (padded).
    qscaled: Vec<f32>,
}

impl Searcher<'_> {
    /// Search: probe lists up to `search_ef` candidates, return top-k.
    /// Identical results to [`IvfIndex::search`] (which delegates here).
    pub fn search(&mut self, query: &[f32], k: usize, search_ef: usize) -> Vec<SearchResult> {
        self.load_query(query);
        self.score_centroids();
        self.sort_probe_order();
        self.scan(k, search_ef.max(k))
    }

    fn load_query(&mut self, query: &[f32]) {
        assert_eq!(query.len(), self.index.dim, "query dim mismatch");
        self.qbuf[..self.index.dim].copy_from_slice(query);
    }

    fn score_centroids(&mut self) {
        self.cscores.clear();
        for c in 0..self.index.lists.len() {
            self.cscores.push((dot_f32(&self.qbuf, self.index.centroid_row(c)), c));
        }
    }

    /// Probe order: centroid score descending, ties to the lower list id
    /// (`total_cmp`, so a NaN query cannot panic the comparator).
    fn sort_probe_order(&mut self) {
        self.cscores.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
    }

    /// Leading lists (of the sorted probe order) covering at least `ef`
    /// candidates.
    fn probe_prefix(&self, ef: usize) -> usize {
        let mut gathered = 0usize;
        for (i, &(_, c)) in self.cscores.iter().enumerate() {
            gathered += self.index.lists[c].len();
            if gathered >= ef {
                return i + 1;
            }
        }
        self.cscores.len()
    }

    /// Stream the probed lists' candidates through the bounded heap.
    fn scan(&mut self, k: usize, ef: usize) -> Vec<SearchResult> {
        match &self.index.sq8 {
            None => self.scan_f32(k, ef),
            Some(_) => self.scan_sq8(k, ef),
        }
    }

    fn scan_f32(&self, k: usize, ef: usize) -> Vec<SearchResult> {
        let idx = self.index;
        let mut top = TopK::new(k);
        let mut gathered = 0usize;
        for &(_, c) in &self.cscores {
            let list = &idx.lists[c];
            for &i in list {
                top.push(i, dot_f32(&self.qbuf, idx.row(i)));
            }
            gathered += list.len();
            if gathered >= ef {
                break;
            }
        }
        top.into_sorted()
    }

    /// SQ8 scan: quantized scoring into a `rerank_factor × k` shortlist,
    /// then an exact f32 rescoring pass picks and orders the final k —
    /// returned scores are exact dot products.
    fn scan_sq8(&mut self, k: usize, ef: usize) -> Vec<SearchResult> {
        let idx = self.index;
        let sq8 = idx.sq8.as_ref().expect("scan_sq8 on an unquantized index");
        for d in 0..idx.stride {
            self.qscaled[d] = self.qbuf[d] * sq8.scales[d];
        }
        let qdotmin = dot_f32(&self.qbuf, &sq8.mins);
        let shortlist_k = k.saturating_mul(idx.rerank_factor).max(k);
        let mut top = TopK::new(shortlist_k);
        let mut gathered = 0usize;
        for &(_, c) in &self.cscores {
            let list = &idx.lists[c];
            for &i in list {
                top.push(i, qdotmin + dot_sq8(&self.qscaled, sq8.row(i, idx.stride)));
            }
            gathered += list.len();
            if gathered >= ef {
                break;
            }
        }
        // Exact rescoring pass over the survivors.
        let mut fin = TopK::new(k);
        for r in top.into_sorted() {
            fin.push(r.id, dot_f32(&self.qbuf, idx.row(r.id)));
        }
        fin.into_sorted()
    }
}

/// Repair degenerate clusters after k-means: duplicate rows or an unlucky
/// init can leave inverted lists empty, silently shrinking the effective
/// `n_lists` (a probe that "covers" such a list gathers nothing, skewing
/// the `search_ef` ↔ recall curve). Each empty list is reseeded from the
/// largest list: the donor's member *least* similar to the donor centroid
/// moves over and becomes the new centroid. Every iteration fills one
/// empty list while leaving the donor non-empty, so the loop terminates
/// with all lists occupied whenever the corpus has ≥ `n_lists` rows.
/// Operates on the padded `[_, stride]` blocks.
fn repair_empty_lists(
    vectors: &[f32],
    stride: usize,
    centroids: &mut [f32],
    lists: &mut [Vec<usize>],
) {
    loop {
        let Some(empty) = lists.iter().position(|l| l.is_empty()) else { break };
        let donor = (0..lists.len())
            .max_by_key(|&c| lists[c].len())
            .expect("at least one list");
        if lists[donor].len() < 2 {
            break; // corpus smaller than n_lists: nothing left to split
        }
        let dc = &centroids[donor * stride..(donor + 1) * stride];
        let (pos, _) = lists[donor]
            .iter()
            .enumerate()
            .map(|(p, &vid)| (p, dot_f32(&vectors[vid * stride..(vid + 1) * stride], dc)))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .expect("donor non-empty");
        let vid = lists[donor].swap_remove(pos);
        lists[empty].push(vid);
        centroids[empty * stride..(empty + 1) * stride]
            .copy_from_slice(&vectors[vid * stride..(vid + 1) * stride]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::workload::corpus::Corpus;

    fn build_test_index(n: usize, dim: usize, seed: u64) -> (IvfIndex, Corpus) {
        let corpus = Corpus::generate(n, 8, 64, seed);
        let mut vectors = Vec::with_capacity(n * dim);
        for p in &corpus.passages {
            vectors.extend(Corpus::hash_embed(&p.text, dim));
        }
        (IvfIndex::build(vectors, dim, IvfParams::default()), corpus)
    }

    fn corpus_vectors(n: usize, dim: usize, seed: u64) -> Vec<f32> {
        let corpus = Corpus::generate(n, 8, 64, seed);
        let mut vectors = Vec::with_capacity(n * dim);
        for p in &corpus.passages {
            vectors.extend(Corpus::hash_embed(&p.text, dim));
        }
        vectors
    }

    #[test]
    fn exact_search_finds_self() {
        let (idx, _) = build_test_index(500, 32, 0);
        for i in [0usize, 100, 499] {
            let q: Vec<f32> = idx.vector(i).to_vec();
            let top = idx.search_exact(&q, 1);
            assert_eq!(top[0].id, i);
        }
    }

    #[test]
    fn higher_ef_higher_recall() {
        let (idx, corpus) = build_test_index(2000, 32, 1);
        let mut qg = crate::workload::queries::QueryGen::new(&corpus, 3);
        let k = 10;
        let mut recalls = Vec::new();
        for ef in [20usize, 200, 2000] {
            let mut total = 0.0;
            let trials = 20;
            for _ in 0..trials {
                let q = qg.next();
                let qe = Corpus::hash_embed(&q.text, 32);
                let got = idx.search(&qe, k, ef);
                let exact = idx.search_exact(&qe, k);
                total += IvfIndex::recall(&got, &exact);
            }
            recalls.push(total / trials as f64);
        }
        assert!(recalls[0] <= recalls[1] + 0.05, "{recalls:?}");
        assert!(recalls[1] <= recalls[2] + 0.05, "{recalls:?}");
        // Full-ef scan must be exact.
        assert!(recalls[2] > 0.999, "{recalls:?}");
    }

    #[test]
    fn candidates_bounded_by_ef_granularity() {
        let (idx, _) = build_test_index(1000, 32, 2);
        let q = idx.vector(0).to_vec();
        let c_small = idx.candidates(&q, 10);
        let c_large = idx.candidates(&q, 1000);
        assert!(c_small.len() < c_large.len());
        assert_eq!(c_large.len(), 1000, "full probe covers corpus");
        // Exact-capacity gather: the diagnostic vector reserves exactly
        // what the probed lists hold (the old path reserved `ef + 64`).
        assert_eq!(c_small.capacity(), c_small.len());
        assert_eq!(c_large.capacity(), c_large.len());
    }

    #[test]
    fn search_results_sorted_and_k_bounded() {
        let (idx, _) = build_test_index(300, 16, 3);
        let q = idx.vector(5).to_vec();
        let res = idx.search(&q, 7, 100);
        assert_eq!(res.len(), 7);
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn lists_partition_the_corpus() {
        let (idx, _) = build_test_index(400, 16, 4);
        let mut seen = vec![false; idx.len()];
        for l in &idx.lists {
            for &i in l {
                assert!(!seen[i], "duplicate membership {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn search_batch_matches_single_query_search() {
        let (idx, corpus) = build_test_index(1500, 32, 8);
        let mut qg = crate::workload::queries::QueryGen::new(&corpus, 5);
        let queries: Vec<Vec<f32>> =
            (0..10).map(|_| Corpus::hash_embed(&qg.next().text, 32)).collect();
        for ef in [30usize, 300, 1500] {
            let batched = idx.search_batch(&queries, 8, ef);
            assert_eq!(batched.len(), queries.len());
            for (q, got) in queries.iter().zip(&batched) {
                let want = idx.search(q, 8, ef);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.id, b.id);
                    assert_eq!(a.score.to_bits(), b.score.to_bits());
                }
            }
        }
    }

    #[test]
    fn search_batch_matches_search_under_sq8() {
        // The bit-identity must survive quantized scanning + rescoring.
        let dim = 24; // deliberately not a LANES multiple
        let vectors = corpus_vectors(900, dim, 0x5108);
        let params =
            IvfParams { quantization: Quantization::SQ8, rerank_factor: 3, ..IvfParams::default() };
        let idx = IvfIndex::build(vectors.clone(), dim, params);
        let queries: Vec<Vec<f32>> =
            (0..8).map(|i| vectors[(i * 97) % 900 * dim..][..dim].to_vec()).collect();
        for ef in [40usize, 300, 900] {
            let batched = idx.search_batch(&queries, 6, ef);
            for (q, got) in queries.iter().zip(&batched) {
                let want = idx.search(q, 6, ef);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.id, b.id);
                    assert_eq!(a.score.to_bits(), b.score.to_bits());
                }
            }
        }
    }

    #[test]
    fn degenerate_clusters_are_repaired() {
        // All rows identical: k-means collapses every row into one list,
        // which without repair leaves n_lists - 1 lists empty.
        let dim = 16;
        let n = 64;
        let one = Corpus::hash_embed(b"the same passage", dim);
        let mut vectors = Vec::with_capacity(n * dim);
        for _ in 0..n {
            vectors.extend_from_slice(&one);
        }
        let idx = IvfIndex::build(
            vectors,
            dim,
            IvfParams { n_lists: 8, kmeans_iters: 4, seed: 3, ..IvfParams::default() },
        );
        let sizes = idx.list_sizes();
        assert_eq!(sizes.len(), 8);
        assert!(sizes.iter().all(|&s| s > 0), "empty list survived repair: {sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), n, "repair must preserve the partition");
    }

    #[test]
    fn repaired_lists_still_partition_clustered_corpus() {
        // A corpus with fewer distinct rows than lists exercises the
        // donor loop repeatedly.
        let dim = 16;
        let a = Corpus::hash_embed(b"topic alpha", dim);
        let b = Corpus::hash_embed(b"topic beta", dim);
        let mut vectors = Vec::new();
        for i in 0..40 {
            vectors.extend_from_slice(if i % 2 == 0 { &a } else { &b });
        }
        let idx = IvfIndex::build(
            vectors,
            dim,
            IvfParams { n_lists: 10, kmeans_iters: 6, seed: 9, ..IvfParams::default() },
        );
        let sizes = idx.list_sizes();
        assert!(sizes.iter().all(|&s| s > 0), "{sizes:?}");
        let mut seen = vec![false; idx.len()];
        for l in &idx.lists {
            for &i in l {
                assert!(!seen[i], "duplicate membership {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn recall_metric_sane() {
        let a = [SearchResult { id: 1, score: 1.0 }, SearchResult { id: 2, score: 0.9 }];
        let b = [SearchResult { id: 1, score: 1.0 }, SearchResult { id: 3, score: 0.8 }];
        assert_eq!(IvfIndex::recall(&a, &b), 0.5);
        assert_eq!(IvfIndex::recall(&a, &a), 1.0);
        assert_eq!(IvfIndex::recall(&[], &[]), 1.0);
    }

    #[test]
    fn search_property_topk_dominates_rest() {
        property("ivf top-k dominance", 10, |g| {
            let n = g.usize(100, 400);
            let (idx, _) = build_test_index(n, 16, g.i64(0, 1 << 20) as u64);
            let qi = g.usize(0, n - 1);
            let q = idx.vector(qi).to_vec();
            let k = g.usize(1, 10);
            let res = idx.search_exact(&q, k);
            // every returned score >= any non-returned score
            let min_ret = res.last().unwrap().score;
            let ids: std::collections::HashSet<usize> = res.iter().map(|r| r.id).collect();
            for i in 0..n {
                if !ids.contains(&i) {
                    let s: f32 = idx.vector(i).iter().zip(&q).map(|(a, b)| a * b).sum();
                    assert!(s <= min_ret + 1e-5);
                }
            }
        });
    }

    // -- blocked kernels ----------------------------------------------------

    #[test]
    fn blocked_dot_matches_scalar_reference() {
        let mut rng = Rng::new(11);
        for len in [1usize, 7, 8, 9, 16, 31, 32, 64, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
            let blocked = dot_f32(&a, &b);
            let scalar: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(
                (blocked - scalar).abs() <= 1e-4 * (1.0 + scalar.abs()),
                "len {len}: {blocked} vs {scalar}"
            );
        }
    }

    #[test]
    fn padded_scores_are_shape_independent() {
        // dim 20 pads to stride 24; the zero tail must not change any
        // score visible through the public API.
        let dim = 20;
        let vectors = corpus_vectors(300, dim, 77);
        let idx = IvfIndex::build(vectors.clone(), dim, IvfParams::default());
        let q = vectors[..dim].to_vec();
        let exact = idx.search_exact(&q, 5);
        for r in &exact {
            // Same padded kernel applied directly to the public row view
            // (dim 20 is not a LANES multiple, so the scalar tail runs).
            let direct = dot_f32(idx.vector(r.id), &q);
            assert!(
                (direct - r.score).abs() <= 1e-5 * (1.0 + direct.abs()),
                "{direct} vs {}",
                r.score
            );
        }
    }

    // -- bounded-heap top-k -------------------------------------------------

    #[test]
    fn topk_matches_select_nth_oracle_with_ties() {
        // Streaming bounded-heap selection must equal the sort-everything
        // oracle exactly: same ids, same scores, same tie order.
        property("bounded-heap top-k == full-sort oracle", 40, |g| {
            let n = g.usize(1, 400);
            let k = g.usize(0, 20);
            // Coarse score grid → plenty of exact ties.
            let scores: Vec<f32> =
                (0..n).map(|_| (g.i64(-5, 5) as f32) / 4.0).collect();
            let mut top = TopK::new(k);
            for (id, &s) in scores.iter().enumerate() {
                top.push(id, s);
            }
            let got = top.into_sorted();
            let mut oracle: Vec<SearchResult> = scores
                .iter()
                .enumerate()
                .map(|(id, &score)| SearchResult { id, score })
                .collect();
            oracle.sort_unstable_by(|a, b| {
                b.score.total_cmp(&a.score).then_with(|| a.id.cmp(&b.id))
            });
            oracle.truncate(k);
            assert_eq!(got.len(), oracle.len());
            for (a, b) in got.iter().zip(&oracle) {
                assert_eq!(a.id, b.id, "tie order diverged from oracle");
                assert_eq!(a.score.to_bits(), b.score.to_bits());
            }
        });
    }

    #[test]
    fn topk_zero_k_is_empty() {
        let mut top = TopK::new(0);
        top.push(1, 1.0);
        top.push(2, f32::NAN);
        assert!(top.is_empty());
        assert!(top.into_sorted().is_empty());
    }

    // -- NaN hardening (PR 7's total_cmp sweep, finished) --------------------

    #[test]
    fn nan_scores_cannot_panic_or_scramble() {
        // A NaN query poisons every centroid and candidate score. The old
        // comparators (`partial_cmp().unwrap()`) panicked outright; the
        // total_cmp paths must stay deterministic and well-formed.
        let (idx, _) = build_test_index(400, 16, 21);
        let mut q = idx.vector(0).to_vec();
        q[3] = f32::NAN;
        let res = idx.search(&q, 5, 100);
        assert_eq!(res.len(), 5, "NaN scores must not shrink the result set");
        let ids: std::collections::HashSet<usize> = res.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), 5, "no duplicate ids under NaN scoring");
        let res2 = idx.search(&q, 5, 100);
        for (a, b) in res.iter().zip(&res2) {
            assert_eq!(a.id, b.id, "NaN ordering must be deterministic");
        }
        // All-NaN scores tie; the deterministic tie order is ascending id.
        for w in res.windows(2) {
            assert!(w[0].id < w[1].id, "NaN tie order must be id-ascending: {res:?}");
        }
        // search_exact and candidates() walk the same comparators.
        assert_eq!(idx.search_exact(&q, 3).len(), 3);
        assert_eq!(idx.candidates(&q, 400).len(), 400);
    }

    #[test]
    fn single_nan_dimension_does_not_scramble_finite_ordering() {
        // A NaN that poisons only *some* rows: finite-scored rows must
        // keep their exact relative order below the NaN block (total_cmp
        // sorts NaN above every finite score).
        let dim = 8;
        let mut vectors = vec![0f32; 4 * dim];
        for (i, row) in vectors.chunks_mut(dim).enumerate() {
            row[0] = 1.0 - i as f32 * 0.25; // scores 1.0, 0.75, 0.5, 0.25
        }
        vectors[3 * dim] = f32::NAN; // row 3 scores NaN
        let idx = IvfIndex::build(
            vectors,
            dim,
            IvfParams { n_lists: 1, kmeans_iters: 0, ..IvfParams::default() },
        );
        let mut q = vec![0f32; dim];
        q[0] = 1.0;
        let res = idx.search_exact(&q, 4);
        assert_eq!(res.len(), 4);
        // NaN ranks first (total_cmp: NaN > +inf), finite rows keep
        // their score-descending order after it.
        assert_eq!(res[0].id, 3, "{res:?}");
        assert!(res[0].score.is_nan());
        assert_eq!(
            res[1..].iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "finite ordering scrambled: {res:?}"
        );
    }

    // -- SQ8 ----------------------------------------------------------------

    #[test]
    fn sq8_round_trip_error_bounded() {
        // Quantize→dequantize must land within half a quantization step
        // per dimension (the grid rounds to nearest).
        property("sq8 round-trip error bound", 12, |g| {
            let n = g.usize(20, 200);
            let dim = g.usize(4, 48);
            let vectors = corpus_vectors(n, dim, g.i64(0, 1 << 24) as u64);
            let sq8 = {
                let idx = IvfIndex::build(
                    vectors.clone(),
                    dim,
                    IvfParams { quantization: Quantization::SQ8, ..IvfParams::default() },
                );
                assert_eq!(idx.quantization(), Quantization::SQ8);
                let sq8 = idx.sq8.as_ref().unwrap();
                for i in 0..n {
                    for d in 0..dim {
                        let v = idx.vector(i)[d];
                        let deq = sq8.dequant(i, d, idx.stride);
                        let bound = sq8.scales[d] * 0.5 + 1e-6;
                        assert!(
                            (deq - v).abs() <= bound,
                            "row {i} dim {d}: |{deq} - {v}| > {bound}"
                        );
                    }
                }
                idx.scan_bytes_per_vector()
            };
            // The SQ8 scan streams exactly one byte per (padded) dim.
            let f32_idx = IvfIndex::build(vectors, dim, IvfParams::default());
            assert_eq!(f32_idx.scan_bytes_per_vector(), 4 * sq8);
        });
    }

    #[test]
    fn sq8_rescored_recall_tracks_f32_recall() {
        // The pinned band: SQ8 + exact rescoring loses at most 0.02
        // recall@10 vs the unquantized index on random corpora.
        property("sq8 recall@10 >= f32 recall@10 - 0.02", 8, |g| {
            let n = g.usize(400, 1200);
            let dim = [16, 24, 32][g.usize(0, 2)];
            let seed = g.i64(0, 1 << 24) as u64;
            let vectors = corpus_vectors(n, dim, seed);
            let base = IvfIndex::build(vectors.clone(), dim, IvfParams::default());
            let quant = IvfIndex::build(
                vectors.clone(),
                dim,
                IvfParams { quantization: Quantization::SQ8, ..IvfParams::default() },
            );
            let ef = g.usize(n / 4, n);
            let k = 10;
            let trials = 8;
            let (mut r_f32, mut r_sq8) = (0.0, 0.0);
            for t in 0..trials {
                let q = vectors[(t * 131) % n * dim..][..dim].to_vec();
                let exact = base.search_exact(&q, k);
                r_f32 += IvfIndex::recall(&base.search(&q, k, ef), &exact);
                r_sq8 += IvfIndex::recall(&quant.search(&q, k, ef), &exact);
            }
            r_f32 /= trials as f64;
            r_sq8 /= trials as f64;
            assert!(
                r_sq8 >= r_f32 - 0.02,
                "sq8 recall {r_sq8} fell more than 0.02 below f32 recall {r_f32} \
                 (n={n} dim={dim} ef={ef} seed={seed})"
            );
        });
    }

    #[test]
    fn sq8_exact_rescoring_returns_exact_scores() {
        // Returned scores must be true f32 dot products (the rescoring
        // pass), not quantized approximations.
        let dim = 32;
        let vectors = corpus_vectors(600, dim, 5);
        let idx = IvfIndex::build(
            vectors.clone(),
            dim,
            IvfParams { quantization: Quantization::SQ8, ..IvfParams::default() },
        );
        let q = vectors[..dim].to_vec();
        for r in idx.search(&q, 8, 600) {
            let exact = dot_f32(idx.vector(r.id), &q);
            assert_eq!(exact.to_bits(), r.score.to_bits(), "score not exactly rescored");
        }
    }

    #[test]
    fn sq8_full_probe_with_wide_shortlist_is_exact() {
        // When the shortlist covers every candidate, SQ8 + rescoring
        // degenerates to the exact search: same ids, same scores.
        let dim = 16;
        let n = 200;
        let vectors = corpus_vectors(n, dim, 9);
        let base = IvfIndex::build(vectors.clone(), dim, IvfParams::default());
        let quant = IvfIndex::build(
            vectors.clone(),
            dim,
            IvfParams {
                quantization: Quantization::SQ8,
                rerank_factor: n, // shortlist ⊇ candidates
                ..IvfParams::default()
            },
        );
        let q = vectors[dim..2 * dim].to_vec();
        let want = base.search(&q, 10, n);
        let got = quant.search(&q, 10, n);
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
    }
}
