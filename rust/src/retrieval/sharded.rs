//! Sharded IVF index: the corpus partitioned across `n_shards`
//! independent [`IvfIndex`] shards searched scatter-gather style.
//!
//! The paper's central observation is that RAG components have
//! *heterogeneous scalability characteristics*: retrieval scales with
//! corpus size and candidate budget, not with GPU count, so it must be
//! partitioned and replicated independently of the LLM stages. This
//! module supplies the data-plane half of that story:
//!
//! * **scatter** — a query (or a whole batch of queries) is sent to every
//!   shard concurrently via scoped threads; each shard runs an ordinary
//!   IVF probe over its slice of the corpus with `search_ef / n_shards`
//!   of the candidate budget;
//! * **gather** — the per-shard top-k lists (already sorted) are combined
//!   with a binary-heap k-way merge, so merge cost is `O((k + S) log S)`
//!   per query rather than `O(S·k log(S·k))`;
//! * **batched search** — [`ShardedIndex::search_batch`] hands each shard
//!   the *entire* query batch, amortizing both the thread fan-out (one
//!   spawn per shard per batch, not per query) and the centroid scoring
//!   inside [`IvfIndex::search_batch`].
//!
//! Rows are assigned to shards round-robin (`global_id % n_shards`), so
//! shard sizes differ by at most one row and every shard sees the same
//! topic mix — the per-shard IVF statistics stay representative of the
//! whole corpus.
//!
//! With the full candidate budget (`search_ef >= len()`) the sharded
//! search degenerates to an exact scan on every shard, and the merged
//! top-k is identical to a single [`IvfIndex`] given the same total
//! budget — the oracle property the tests below pin down.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::store::{IvfIndex, IvfParams, SearchResult};

/// Construction parameters for a [`ShardedIndex`].
#[derive(Clone, Copy, Debug)]
pub struct ShardParams {
    /// Number of corpus partitions (1 = plain single-index behavior).
    pub n_shards: usize,
    /// IVF parameters; `ivf.n_lists` is the *total* list budget, divided
    /// evenly across shards so aggregate centroid-scoring work matches a
    /// single index over the whole corpus.
    pub ivf: IvfParams,
}

impl Default for ShardParams {
    fn default() -> Self {
        ShardParams { n_shards: 4, ivf: IvfParams::default() }
    }
}

/// One corpus partition: a local IVF index plus the local→global id map.
struct Shard {
    /// Global corpus id of each local row (`ids[local] == global`).
    ids: Vec<usize>,
    /// `None` when the shard received no rows (corpus smaller than the
    /// shard count).
    index: Option<IvfIndex>,
}

impl Shard {
    /// Search this shard's slice; hits are rewritten to global ids.
    fn search_batch_local(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        search_ef: usize,
    ) -> Vec<Vec<SearchResult>> {
        match &self.index {
            None => vec![Vec::new(); queries.len()],
            Some(idx) => idx
                .search_batch(queries, k, search_ef)
                .into_iter()
                .map(|hits| {
                    hits.into_iter()
                        .map(|h| SearchResult { id: self.ids[h.id], score: h.score })
                        .collect()
                })
                .collect(),
        }
    }
}

/// The corpus partitioned across independent IVF shards, searched with
/// parallel scatter-gather and merged with a k-way heap merge.
pub struct ShardedIndex {
    dim: usize,
    len: usize,
    shards: Vec<Shard>,
}

impl ShardedIndex {
    /// Partition row-major `vectors` ([n, dim]) across `params.n_shards`
    /// shards (round-robin by row id) and build one IVF index per
    /// non-empty shard. Deterministic for (vectors, dim, params).
    pub fn build(vectors: Vec<f32>, dim: usize, params: ShardParams) -> ShardedIndex {
        assert!(dim > 0 && vectors.len() % dim == 0);
        let n = vectors.len() / dim;
        let n_shards = params.n_shards.max(1);
        let per_shard_lists = (params.ivf.n_lists / n_shards).max(1);

        let mut shard_vecs: Vec<Vec<f32>> = (0..n_shards).map(|_| Vec::new()).collect();
        let mut shard_ids: Vec<Vec<usize>> = (0..n_shards).map(|_| Vec::new()).collect();
        for g in 0..n {
            let s = g % n_shards;
            shard_vecs[s].extend_from_slice(&vectors[g * dim..(g + 1) * dim]);
            shard_ids[s].push(g);
        }

        let shards = shard_ids
            .into_iter()
            .zip(shard_vecs)
            .enumerate()
            .map(|(s, (ids, vecs))| {
                let index = if ids.is_empty() {
                    None
                } else {
                    Some(IvfIndex::build(
                        vecs,
                        dim,
                        IvfParams {
                            n_lists: per_shard_lists,
                            // Decorrelate shard k-means runs while keeping
                            // the whole build a pure function of the seed.
                            seed: params.ivf.seed
                                ^ (s as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                            // Quantization mode (and rerank factor) apply
                            // per shard — each shard quantizes on its own
                            // slice's per-dim min/max.
                            ..params.ivf
                        },
                    ))
                };
                Shard { ids, index }
            })
            .collect();

        ShardedIndex { dim, len: n, shards }
    }

    /// Total rows across all shards.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Rows held by shard `s`.
    pub fn shard_len(&self, s: usize) -> usize {
        self.shards[s].ids.len()
    }

    /// Per-shard candidate budget: the total `search_ef` divided evenly
    /// (rounded up so the aggregate budget is never *under* the request).
    fn per_shard_ef(&self, search_ef: usize, k: usize) -> usize {
        let s = self.shards.len().max(1);
        search_ef.max(k).div_ceil(s)
    }

    /// Scatter-gather search for one query: probe every shard in parallel
    /// with `search_ef / n_shards` of the candidate budget, then k-way
    /// merge the per-shard top-k lists.
    pub fn search(&self, query: &[f32], k: usize, search_ef: usize) -> Vec<SearchResult> {
        let q = vec![query.to_vec()];
        self.search_batch(&q, k, search_ef).pop().unwrap_or_default()
    }

    /// Batched scatter-gather: every shard receives the whole query batch
    /// on its own thread (one spawn per shard per batch); per-query merges
    /// happen on the calling thread.
    pub fn search_batch(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        search_ef: usize,
    ) -> Vec<Vec<SearchResult>> {
        for q in queries {
            assert_eq!(q.len(), self.dim, "query dim mismatch");
        }
        if queries.is_empty() {
            return Vec::new();
        }
        let ef = self.per_shard_ef(search_ef, k);
        let per_shard = self.scatter(queries, k, ef);
        (0..queries.len())
            .map(|qi| {
                let lists: Vec<&[SearchResult]> =
                    per_shard.iter().map(|s| s[qi].as_slice()).collect();
                merge_topk(&lists, k)
            })
            .collect()
    }

    /// Exact top-k (ground truth): every shard scans its full slice.
    pub fn search_exact(&self, query: &[f32], k: usize) -> Vec<SearchResult> {
        self.search(query, k, self.len.max(1))
    }

    /// Run `search_batch_local` on every shard concurrently.
    fn scatter(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        ef_per_shard: usize,
    ) -> Vec<Vec<Vec<SearchResult>>> {
        if self.shards.len() <= 1 {
            return self
                .shards
                .iter()
                .map(|s| s.search_batch_local(queries, k, ef_per_shard))
                .collect();
        }
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter()
                .map(|sh| scope.spawn(move || sh.search_batch_local(queries, k, ef_per_shard)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard search thread panicked"))
                .collect()
        })
    }
}

/// Heap entry for the k-way merge. Ordered by score descending with ties
/// broken toward the lower global id, so merged results are deterministic
/// and match the single-index sort order.
struct HeapEntry {
    score: f32,
    id: usize,
    shard: usize,
    pos: usize,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: greater = popped first. Higher score
        // wins; on ties the lower id wins. `total_cmp` keeps the order
        // total under NaN scores (the old `partial_cmp().unwrap_or(Equal)`
        // silently collapsed NaN entries into spurious "ties", scrambling
        // the merge instead of ranking NaN deterministically above +inf
        // like the per-shard bounded heap does).
        self.score.total_cmp(&other.score).then_with(|| other.id.cmp(&self.id))
    }
}

/// k-way merge of per-shard result lists (each sorted by score desc) into
/// a single global top-k. `O((k + S) log S)` per query.
fn merge_topk(lists: &[&[SearchResult]], k: usize) -> Vec<SearchResult> {
    let mut heap = BinaryHeap::with_capacity(lists.len());
    for (si, l) in lists.iter().enumerate() {
        if let Some(first) = l.first() {
            heap.push(HeapEntry { score: first.score, id: first.id, shard: si, pos: 0 });
        }
    }
    let avail: usize = lists.iter().map(|l| l.len()).sum();
    let mut out = Vec::with_capacity(k.min(avail));
    while out.len() < k {
        let Some(top) = heap.pop() else { break };
        out.push(SearchResult { id: top.id, score: top.score });
        let next = top.pos + 1;
        if let Some(r) = lists[top.shard].get(next) {
            heap.push(HeapEntry { score: r.score, id: r.id, shard: top.shard, pos: next });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retrieval::store::{dot_f32, Quantization};
    use crate::workload::corpus::Corpus;

    const DIM: usize = 32;

    fn corpus_vectors(n: usize, seed: u64) -> Vec<f32> {
        let corpus = Corpus::generate(n, 8, 64, seed);
        let mut vectors = Vec::with_capacity(n * DIM);
        for p in &corpus.passages {
            vectors.extend(Corpus::hash_embed(&p.text, DIM));
        }
        vectors
    }

    fn queries_from(vectors: &[f32], n_q: usize) -> Vec<Vec<f32>> {
        (0..n_q)
            .map(|i| {
                let row = (i * 37) % (vectors.len() / DIM);
                vectors[row * DIM..(row + 1) * DIM].to_vec()
            })
            .collect()
    }

    /// Canonical ordering for comparison: (score desc, id asc). The
    /// single-index path may order equal scores arbitrarily.
    fn canon(mut r: Vec<SearchResult>) -> Vec<(usize, f32)> {
        r.sort_by(|a, b| b.score.total_cmp(&a.score).then_with(|| a.id.cmp(&b.id)));
        r.into_iter().map(|h| (h.id, h.score)).collect()
    }

    #[test]
    fn oracle_exact_matches_single_index_at_full_budget() {
        // With the full search_ef budget both paths are exact scans, so
        // the sharded top-k must equal the single-index top-k: same ids,
        // same scores (scores are computed by the same dot-product code
        // on the same rows, so they are bitwise equal).
        let n = 1200;
        let vectors = corpus_vectors(n, 0xA11CE);
        let single = IvfIndex::build(vectors.clone(), DIM, IvfParams::default());
        for n_shards in [1usize, 3, 4, 8] {
            let sharded = ShardedIndex::build(
                vectors.clone(),
                DIM,
                ShardParams { n_shards, ivf: IvfParams::default() },
            );
            for q in queries_from(&vectors, 12) {
                let want = canon(single.search(&q, 10, n));
                let got = canon(sharded.search(&q, 10, n));
                assert_eq!(got, want, "n_shards={n_shards}");
            }
        }
    }

    #[test]
    fn oracle_property_randomized_corpora_shards_and_duplicates() {
        // Property form of the oracle: across randomized corpus sizes,
        // shard counts S ∈ {1..8}, and duplicate-heavy corpora, the
        // sharded merged top-k at full `search_ef` equals the single
        // IvfIndex top-k (canonical (score desc, id asc) order — equal
        // scores may be permuted within a tie by either path).
        use crate::util::proptest::property;
        property("sharded == single-index oracle", 12, |g| {
            let n = g.usize(40, 600);
            let seed = g.i64(0, 1 << 24) as u64;
            let n_shards = g.usize(1, 8);
            let duplicate_heavy = g.bool();
            let mut vectors = corpus_vectors(n, seed);
            if duplicate_heavy {
                // Collapse most rows onto a handful of distinct vectors:
                // exercises tie-breaking in the k-way merge and the
                // degenerate-cluster repair inside each shard.
                let distinct = g.usize(1, 4);
                for i in distinct..n {
                    let src = i % distinct;
                    let (a, b) = vectors.split_at_mut(i * DIM);
                    b[..DIM].copy_from_slice(&a[src * DIM..(src + 1) * DIM]);
                }
            }
            let ivf = IvfParams {
                n_lists: g.usize(2, 32),
                kmeans_iters: 4,
                seed,
                ..IvfParams::default()
            };
            let single = IvfIndex::build(vectors.clone(), DIM, ivf);
            let sharded =
                ShardedIndex::build(vectors.clone(), DIM, ShardParams { n_shards, ivf });
            let k = g.usize(1, 12);
            for q in queries_from(&vectors, 4) {
                let want = canon(single.search(&q, k, n));
                let got = canon(sharded.search(&q, k, n));
                assert_eq!(
                    got.len(),
                    want.len(),
                    "n={n} S={n_shards} k={k} dup={duplicate_heavy}"
                );
                for (a, b) in got.iter().zip(&want) {
                    // Ids may differ inside an exact score tie (duplicate
                    // rows are interchangeable); scores must be identical.
                    assert_eq!(a.1.to_bits(), b.1.to_bits(), "score mismatch at n={n} S={n_shards}");
                }
                // The id multisets must agree up to tie groups: every
                // returned id must score exactly its returned score
                // (recomputed through the same blocked kernel the index
                // uses — DIM is a LANES multiple, so the padded internal
                // rows and these raw slices share one summation shape).
                for &(id, score) in &got {
                    let s = dot_f32(&vectors[id * DIM..(id + 1) * DIM], &q);
                    assert_eq!(s.to_bits(), score.to_bits(), "stale id→score pair");
                }
            }
        });
    }

    #[test]
    fn batched_search_matches_sequential_search() {
        let n = 800;
        let vectors = corpus_vectors(n, 7);
        let idx = ShardedIndex::build(vectors.clone(), DIM, ShardParams::default());
        let queries = queries_from(&vectors, 9);
        let batched = idx.search_batch(&queries, 5, 200);
        for (q, want_src) in queries.iter().zip(&batched) {
            let got = idx.search(q, 5, 200);
            assert_eq!(canon(got), canon(want_src.clone()));
        }
    }

    #[test]
    fn sharded_recall_tracks_single_index_recall() {
        // At a moderate ef budget the sharded probe is a different (not
        // identical) candidate set, but recall must stay in the same
        // regime as the single index — sharding is a throughput/latency
        // lever, not a quality cliff.
        let n = 2000;
        let vectors = corpus_vectors(n, 0xBEE);
        let single = IvfIndex::build(vectors.clone(), DIM, IvfParams::default());
        let sharded = ShardedIndex::build(vectors.clone(), DIM, ShardParams::default());
        let queries = queries_from(&vectors, 16);
        let (mut r_single, mut r_sharded) = (0.0, 0.0);
        for q in &queries {
            let exact = single.search_exact(q, 10);
            r_single += IvfIndex::recall(&single.search(q, 10, 400), &exact);
            r_sharded += IvfIndex::recall(&sharded.search(q, 10, 400), &exact);
        }
        let nq = queries.len() as f64;
        r_single /= nq;
        r_sharded /= nq;
        assert!(
            r_sharded > r_single - 0.15,
            "sharded recall {r_sharded} vs single {r_single}"
        );
    }

    #[test]
    fn empty_shards_are_tolerated() {
        // 3 rows over 8 shards: five shards are empty.
        let vectors = corpus_vectors(3, 1);
        let idx = ShardedIndex::build(
            vectors.clone(),
            DIM,
            ShardParams { n_shards: 8, ivf: IvfParams::default() },
        );
        assert_eq!(idx.len(), 3);
        assert_eq!(idx.n_shards(), 8);
        assert_eq!((0..8).map(|s| idx.shard_len(s)).sum::<usize>(), 3);
        let q = vectors[..DIM].to_vec();
        let hits = idx.search(&q, 2, 100);
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].id, 0, "self-match first");
    }

    #[test]
    fn k_larger_than_corpus_returns_everything_sorted() {
        let vectors = corpus_vectors(5, 2);
        let idx = ShardedIndex::build(vectors.clone(), DIM, ShardParams::default());
        let q = vectors[..DIM].to_vec();
        let hits = idx.search(&q, 50, 1000);
        assert_eq!(hits.len(), 5, "k > corpus returns all rows");
        for w in hits.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        let mut ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn deterministic_under_fixed_seed() {
        let vectors = corpus_vectors(600, 99);
        let params = ShardParams { n_shards: 4, ivf: IvfParams { seed: 42, ..IvfParams::default() } };
        let a = ShardedIndex::build(vectors.clone(), DIM, params);
        let b = ShardedIndex::build(vectors.clone(), DIM, params);
        for q in queries_from(&vectors, 8) {
            let ra = a.search(&q, 7, 150);
            let rb = b.search(&q, 7, 150);
            assert_eq!(ra.len(), rb.len());
            for (x, y) in ra.iter().zip(&rb) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score, y.score);
            }
        }
    }

    #[test]
    fn round_robin_assignment_balances_shards() {
        let vectors = corpus_vectors(101, 3);
        let idx = ShardedIndex::build(
            vectors,
            DIM,
            ShardParams { n_shards: 4, ivf: IvfParams::default() },
        );
        let sizes: Vec<usize> = (0..4).map(|s| idx.shard_len(s)).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 101);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1, "{sizes:?}");
    }

    #[test]
    fn sq8_sharded_search_is_deterministic_and_sane() {
        // Quantization threads through ShardParams: the sharded path must
        // stay deterministic, and with a full budget + wide shortlist the
        // exact rescoring pass makes it equal the f32 sharded search.
        let n = 600;
        let vectors = corpus_vectors(n, 0x5108);
        let ivf = IvfParams {
            quantization: Quantization::SQ8,
            rerank_factor: n, // shortlist ⊇ candidates → exact
            ..IvfParams::default()
        };
        let f32_idx = ShardedIndex::build(
            vectors.clone(),
            DIM,
            ShardParams { n_shards: 4, ivf: IvfParams::default() },
        );
        let sq8_idx = ShardedIndex::build(vectors.clone(), DIM, ShardParams { n_shards: 4, ivf });
        for q in queries_from(&vectors, 6) {
            let want = f32_idx.search(&q, 8, n);
            let got = sq8_idx.search(&q, 8, n);
            assert_eq!(want.len(), got.len());
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.score.to_bits(), b.score.to_bits());
            }
        }
    }

    #[test]
    fn nan_scores_merge_without_panic_or_scramble() {
        // A NaN query used to panic the merge comparator (or collapse NaN
        // into fake ties under `unwrap_or(Equal)`). With total_cmp, NaN
        // entries rank deterministically above all finite scores and the
        // finite suffix keeps its order.
        let vectors = corpus_vectors(300, 17);
        let idx = ShardedIndex::build(
            vectors.clone(),
            DIM,
            ShardParams { n_shards: 4, ivf: IvfParams::default() },
        );
        let mut q = vectors[..DIM].to_vec();
        q[0] = f32::NAN;
        let hits = idx.search(&q, 10, 300);
        assert_eq!(hits.len(), 10, "NaN must not shrink the merged result set");
        let ids: std::collections::HashSet<usize> = hits.iter().map(|h| h.id).collect();
        assert_eq!(ids.len(), 10, "duplicate ids in merged NaN results");
        let hits2 = idx.search(&q, 10, 300);
        for (a, b) in hits.iter().zip(&hits2) {
            assert_eq!(a.id, b.id, "NaN merge must be deterministic");
        }
        // Direct merge-level check: one NaN entry among finite lists.
        let a = [SearchResult { id: 2, score: f32::NAN }, SearchResult { id: 5, score: 0.4 }];
        let b = [SearchResult { id: 1, score: 0.9 }, SearchResult { id: 7, score: 0.1 }];
        let merged = merge_topk(&[a.as_slice(), b.as_slice()], 4);
        let ids: Vec<usize> = merged.iter().map(|h| h.id).collect();
        assert_eq!(ids, vec![2, 1, 5, 7], "NaN ranks first, finite order preserved");
    }

    #[test]
    fn merge_topk_interleaves_and_breaks_ties_by_id() {
        let a = [
            SearchResult { id: 4, score: 0.9 },
            SearchResult { id: 0, score: 0.5 },
        ];
        let b = [
            SearchResult { id: 3, score: 0.7 },
            SearchResult { id: 1, score: 0.5 },
        ];
        let merged = merge_topk(&[a.as_slice(), b.as_slice()], 4);
        let ids: Vec<usize> = merged.iter().map(|h| h.id).collect();
        // 0.5 tie: id 0 before id 1.
        assert_eq!(ids, vec![4, 3, 0, 1]);
        let merged2 = merge_topk(&[a.as_slice(), b.as_slice()], 2);
        assert_eq!(merged2.len(), 2);
    }
}
