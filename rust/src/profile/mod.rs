//! Profiling layer (§3.2 "Profiling"): estimates the allocation-model
//! parameters — throughput coefficients α_{i,k}, amplification factors
//! γ_i, and routing proportions p_{i,j} — by executing the pipeline over a
//! sample workload.
//!
//! [`models`] holds the calibrated component latency models (the
//! simulator's ground truth, standing in for the authors' A100 testbed);
//! [`profiler`] runs sample requests through those models (or through live
//! components) and produces a [`profiler::Profile`] consumed by the
//! allocator and the runtime controller.

pub mod models;
pub mod profiler;

pub use models::{
    DecodeCostModel, GenBatching, GenPlacement, KvTransferModel, LatencyModel, RequestFeatures,
};
pub use profiler::{
    graph_latency, profile_graph, profile_graph_gen, profile_graph_gen_at, GenSplit, Profile,
};
