//! The profiling phase: estimate α_{i,k}, γ_i and p_{i,j} from sample
//! executions (the paper uses ~100 ShareGPT samples at startup; the
//! runtime re-estimates the same quantities online from telemetry).

use std::collections::HashMap;

use crate::profile::models::{
    instance_concurrency, kv_prefix_service_factor, DecodeCostModel, GenBatching, GenPlacement,
    KvTransferModel, LatencyModel, RequestFeatures,
};
use crate::spec::graph::{Adjacency, ComponentKind, ForkGroup, NodeId, PipelineGraph, ResourceKind};
use crate::util::rng::Rng;
use crate::workload::TraceConfig;

/// Mean per-visit prefill/decode decomposition for a generator node —
/// the quantity the disaggregated LP columns and placement-aware
/// admission priors are built from. `prefill + decode` equals the node's
/// `mean_service` exactly (same samples, split by the noise-free cost
/// ratio, no extra rng draws).
#[derive(Clone, Copy, Debug, Default)]
pub struct GenSplit {
    /// Mean prefill service per visit (seconds).
    pub prefill: f64,
    /// Mean decode service per visit (seconds).
    pub decode: f64,
    /// Mean prefilled prompt tokens per visit (sizes the KV handoff).
    pub prompt_tokens: f64,
}

impl GenSplit {
    pub fn total(&self) -> f64 {
        self.prefill + self.decode
    }
}

/// Estimated parameters for the allocation model.
#[derive(Clone, Debug)]
pub struct Profile {
    /// Mean service time per node (seconds, single request).
    pub mean_service: HashMap<NodeId, f64>,
    /// Throughput coefficient α_{i,k}: req/s contributed per unit of k.
    pub alpha: HashMap<(NodeId, ResourceKind), f64>,
    /// Empirical routing probabilities p_{i,j} keyed by edge index.
    pub edge_probs: Vec<f64>,
    /// Empirical amplification γ_i.
    pub gamma: HashMap<NodeId, f64>,
    /// Prefill/decode decomposition for generator nodes (empty for
    /// graphs without generators).
    pub gen_split: HashMap<NodeId, GenSplit>,
    /// Number of samples profiled.
    pub samples: usize,
}

impl Profile {
    pub fn alpha_for(&self, node: NodeId, k: ResourceKind) -> f64 {
        *self.alpha.get(&(node, k)).unwrap_or(&0.0)
    }

    /// Mean per-visit generator service under a placement. Collocated:
    /// the profiled aggregate, untouched. Disaggregated: the critical
    /// path through the split — prefill (discounted by the KV-prefix
    /// cache's expected hit rate) + KV handoff + decode. Non-generator
    /// nodes always return their plain mean.
    pub fn placement_service(
        &self,
        node: NodeId,
        placement: GenPlacement,
        kv: &KvTransferModel,
        kv_prefix_hit: f64,
    ) -> f64 {
        let base = self.mean_service.get(&node).copied().unwrap_or(0.0);
        match (placement, self.gen_split.get(&node)) {
            (GenPlacement::Disaggregated, Some(s)) => {
                s.prefill * kv_prefix_service_factor(kv_prefix_hit)
                    + kv.cost(s.prompt_tokens.round() as usize)
                    + s.decode
            }
            _ => base,
        }
    }

    /// Placement-aware `mean_service` priors for the admission control
    /// plane (`sched::SlackPredictor` seeds). Under `Collocated` this is
    /// the plain prior map; under `Disaggregated`, generator entries are
    /// re-priced by [`Profile::placement_service`] so admission slack
    /// sees the pool the request will actually wait on instead of the
    /// monolithic aggregate — the over-shedding fix when only the decode
    /// pool saturates.
    pub fn placement_priors(
        &self,
        placement: GenPlacement,
        kv: &KvTransferModel,
        kv_prefix_hit: f64,
    ) -> HashMap<NodeId, f64> {
        self.mean_service
            .keys()
            .map(|&id| (id, self.placement_service(id, placement, kv, kv_prefix_hit)))
            .collect()
    }
}

/// The sampling walk's shared state: graph indexes from the spec
/// compiler's `AnalyzedGraph` (adjacency + dense fork map, built once
/// per profile instead of per hop) and the accumulators the walk fills.
/// Everything is `NodeId.0`-indexed — no hashing on the per-hop path.
struct ProfileWalk<'a> {
    graph: &'a PipelineGraph,
    adj: Adjacency,
    fork_map: Vec<Option<ForkGroup>>,
    trace_cfg: TraceConfig,
    dcm: DecodeCostModel,
    gen: GenBatching,
    gen_occupancy: usize,
    service_sums: Vec<(f64, usize)>,
    /// Generator-only (prefill, decode, prompt-token) sums — the same
    /// sampled service split by the noise-free cost ratio, so the split
    /// consumes no rng draws and sums exactly to `service_sums`.
    split_sums: Vec<(f64, f64, f64)>,
    edge_counts: Vec<usize>,
    node_exits: Vec<usize>,
    hops: usize,
}

impl ProfileWalk<'_> {
    /// Walk one segment: from `cur` until the sink or `stop` (a fork
    /// branch's join, exclusive). Fork-free graphs take exactly the
    /// pre-fork code path — same visits, same rng draws, bit-identical
    /// profiles. At a fork every branch is walked in edge order (each
    /// fork edge counted once per traversal, the fork's exit once), then
    /// the walk resumes at the join.
    fn segment(
        &mut self,
        rng: &mut Rng,
        feats: &RequestFeatures,
        mut cur: NodeId,
        stop: Option<NodeId>,
    ) {
        while cur != self.graph.sink && Some(cur) != stop && self.hops < 1000 {
            self.hops += 1;
            let node = self.graph.node(cur);
            let model = LatencyModel::for_kind(&node.kind);
            // Generator visits under an explicit batching model: price
            // the visit with the decomposed prefill+decode cost at the
            // instance's steady-state occupancy. Static batching further
            // inflates the decode count to the expected batch maximum
            // (Monte-Carlo over B−1 co-batched draws from the same
            // workload the trace generator uses) — the run-to-completion
            // penalty the LP previously never saw.
            let batched_gen =
                matches!(node.kind, ComponentKind::Generator) && self.gen != GenBatching::Legacy;
            // Sharded components scatter-gather: per-request service time
            // shrinks by the calibrated shard factor, and the resulting α
            // is already the *per-shard-pool* coefficient the LP uses.
            let mut t = if batched_gen {
                let b = self.gen_occupancy.max(1);
                let base = match self.gen {
                    GenBatching::Continuous => self.dcm.continuous(feats, b),
                    _ => {
                        let mut max_steps = feats.gen_len;
                        for _ in 1..b {
                            let co = self.trace_cfg.sample_gen_len(rng);
                            max_steps = max_steps.max(co);
                        }
                        self.dcm.static_batch(feats, max_steps, b)
                    }
                };
                base * model.noise(rng)
            } else {
                model.sample(feats, rng)
            };
            t *= crate::profile::models::shard_service_factor(node.shards);
            // Quantized index scans (SQ8) run at the calibrated fraction
            // of the f32 scan. Pure multiply, no rng draw — profiles of
            // unquantized graphs (factor exactly 1.0) stay bit-identical.
            t *= crate::profile::models::quantized_service_factor(node.quantized);
            // Cached components: a `cache_hit_rate` fraction of visits
            // costs only the hit fraction (sampled, same model the DES
            // uses), so the profiled α — and with it the LP priors and
            // the autoscaler targets — is cache-adjusted. The rng draw
            // happens only for cached nodes, keeping uncached profiles
            // bit-identical to the pre-cache code path.
            if node.cache_hit_rate > 0.0 && rng.chance(node.cache_hit_rate) {
                t *= crate::profile::models::CACHE_HIT_COST_FRAC;
            }
            let e = &mut self.service_sums[cur.0];
            e.0 += t;
            e.1 += 1;
            // Generator visits: attribute the sampled service to the
            // prefill and decode phases by the noise-free cost ratio
            // (multiplicative noise and the shard/cache multipliers scale
            // both phases alike, so the ratio is exact). Pure arithmetic
            // — no rng draws — keeping legacy profiles bit-identical.
            if matches!(node.kind, ComponentKind::Generator) {
                let prefill_mean = self.dcm.prefill(feats.prompt_len);
                // Noise-free total for the ratio: continuous@B under the
                // batched modes (static's batch-max inflation is decode-
                // side, so this slightly over-weights prefill — fine for
                // a prior), the legacy aggregate mean otherwise (equal to
                // continuous@1 by the calibration identity).
                let total = if batched_gen {
                    self.dcm.continuous(feats, self.gen_occupancy.max(1))
                } else {
                    model.mean(feats)
                };
                let pf = (prefill_mean / total.max(1e-12)).clamp(0.0, 1.0);
                let s = &mut self.split_sums[cur.0];
                let p_part = t * pf;
                s.0 += p_part;
                s.1 += t - p_part;
                s.2 += feats.prompt_len as f64;
            }
            // Parallel fan-out: traverse every branch, then resume at
            // the join. Each fork edge fires once per traversal while
            // the node exits once — the empirical branch "probability"
            // the LP sees is exactly 1 per branch (full flow).
            if let Some(fg) = self.fork_map[cur.0].as_ref() {
                let fg = fg.clone();
                for &ei in &fg.edges {
                    self.edge_counts[ei] += 1;
                }
                self.node_exits[cur.0] += 1;
                for &entry in &fg.targets {
                    self.segment(rng, feats, entry, Some(fg.join));
                }
                cur = fg.join;
                continue;
            }
            // Sample next edge (probabilistic routing).
            let edges = self.adj.out_edges(cur);
            if edges.is_empty() {
                break;
            }
            let weights: Vec<f64> = edges.iter().map(|&i| self.graph.edges[i].prob()).collect();
            let pick = edges[rng.weighted(&weights)];
            self.edge_counts[pick] += 1;
            self.node_exits[cur.0] += 1;
            cur = self.graph.edges[pick].to;
        }
    }
}

/// Profile a pipeline against the calibrated latency models by sampling
/// `n` requests' features and walking the graph (branch decisions sampled
/// from the spec priors — at deploy time those are the best estimates;
/// the runtime controller replaces them with observed frequencies).
pub fn profile_graph(graph: &PipelineGraph, n: usize, seed: u64) -> Profile {
    profile_graph_gen(graph, n, seed, GenBatching::Legacy)
}

/// [`profile_graph`] with an explicit generator-batching model. With
/// `GenBatching::Static`/`Continuous`, generator visits are priced by the
/// occupancy-aware [`DecodeCostModel`] instead of the aggregate latency
/// model — so the LP's α priors, the autoscaler's targets, and (through
/// the `mean_service` priors seeding `sched::SlackPredictor`) the
/// admission controller's slack predictions all see what a batched decode
/// step actually costs. `GenBatching::Legacy` consumes exactly the same
/// rng stream as the pre-batching profiler, keeping existing profiles
/// bit-identical.
pub fn profile_graph_gen(graph: &PipelineGraph, n: usize, seed: u64, gen: GenBatching) -> Profile {
    // DES-consistent steady-state occupancy: the simulator's generator
    // instances expose `instance_concurrency` decode slots.
    profile_graph_gen_at(graph, n, seed, gen, instance_concurrency(&ComponentKind::Generator))
}

/// [`profile_graph_gen`] with an explicit generator decode occupancy /
/// batch size. The live path prices its prior at the engine's *actual*
/// bucket (the largest compiled batch size — `WORKER_SLOTS` slots per
/// live worker), which is larger than the DES's per-instance slot count;
/// passing it here keeps the deploy-time prior, and with it the LP α and
/// admission slack, in agreement with what the live workers really run.
pub fn profile_graph_gen_at(
    graph: &PipelineGraph,
    n: usize,
    seed: u64,
    gen: GenBatching,
    gen_occupancy: usize,
) -> Profile {
    let mut rng = Rng::new(seed);
    // One analysis pass supplies both the adjacency index and the dense
    // fork map; the walk itself allocates its accumulators per node id.
    let az = graph.analyze();
    let mut walk = ProfileWalk {
        graph,
        adj: az.adj,
        fork_map: az.fork_map,
        trace_cfg: TraceConfig::default(),
        dcm: DecodeCostModel::generator(),
        gen,
        gen_occupancy,
        service_sums: vec![(0.0, 0); graph.nodes.len()],
        split_sums: vec![(0.0, 0.0, 0.0); graph.nodes.len()],
        edge_counts: vec![0usize; graph.edges.len()],
        node_exits: vec![0usize; graph.nodes.len()],
        hops: 0,
    };

    for _ in 0..n {
        let feats = walk.trace_cfg.sample_features(&mut rng);
        // Walk the graph from source, sampling branches; fork groups
        // traverse every branch (sequentially here — the profiler cares
        // about per-node work, not wall-clock overlap).
        walk.hops = 0;
        walk.segment(&mut rng, &feats, graph.source, None);
    }
    let ProfileWalk { service_sums, split_sums, edge_counts, node_exits, .. } = walk;

    let mut mean_service = HashMap::new();
    let mut alpha = HashMap::new();
    let mut gen_split = HashMap::new();
    for node in &graph.nodes {
        let (sum, cnt) = service_sums[node.id.0];
        let mean = if cnt > 0 { sum / cnt as f64 } else { 0.0 };
        mean_service.insert(node.id, mean);
        if matches!(node.kind, ComponentKind::Generator) && cnt > 0 {
            let (p, d, tok) = split_sums[node.id.0];
            gen_split.insert(
                node.id,
                GenSplit {
                    prefill: p / cnt as f64,
                    decode: d / cnt as f64,
                    prompt_tokens: tok / cnt as f64,
                },
            );
        }
        if mean > 0.0 {
            let conc = instance_concurrency(&node.kind) as f64;
            // Per-instance throughput = concurrency / mean service time.
            // α_{i,k} divides that rate by the units of k one instance uses,
            // attributed to the node's primary resource(s).
            for &(k, units) in &node.resources {
                if units > 0.0 {
                    alpha.insert((node.id, k), conc / mean / units);
                }
            }
        }
    }

    let edge_probs: Vec<f64> = graph
        .edges
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let exits = node_exits[e.from.0];
            if exits == 0 {
                e.prob() // unvisited: keep prior (1.0 for fork edges)
            } else {
                edge_counts[i] as f64 / exits as f64
            }
        })
        .collect();

    // γ is structural for our apps (no fan-out components); keep spec value
    // but expose the hook for amplifying components.
    let gamma = graph.nodes.iter().map(|n| (n.id, n.gamma)).collect();

    Profile { mean_service, alpha, edge_probs, gamma, gen_split, samples: n }
}

/// Expected end-to-end **latency** of one request under `mean_service`
/// priors. For fork-free graphs this is the familiar visit-rate-weighted
/// sum of node means; with parallel dataflow it becomes a critical-path
/// estimate — each fork group contributes only its slowest branch (the
/// k-th fastest for `FirstK(k)` joins), because sibling branches overlap
/// in time instead of adding (`PipelineGraph::latency_edge_weights`).
/// This is the latency model behind `sched::SlackPredictor`'s
/// remaining-time estimates, and the reason a fork cuts TTFT while the
/// allocation LP still provisions every branch at full flow.
pub fn graph_latency(graph: &PipelineGraph, mean_service: &HashMap<NodeId, f64>) -> f64 {
    let w = graph.latency_edge_weights(mean_service);
    let n = graph.nodes.len();
    let mut v = vec![0.0f64; n];
    v[graph.source.0] = 1.0;
    for _ in 0..10_000 {
        let mut nv = vec![0.0f64; n];
        nv[graph.source.0] = 1.0;
        for (i, e) in graph.edges.iter().enumerate() {
            nv[e.to.0] += v[e.from.0] * graph.node(e.from).gamma * w[i];
        }
        let diff: f64 = nv.iter().zip(&v).map(|(a, b)| (a - b).abs()).sum();
        v = nv;
        if diff < 1e-12 {
            break;
        }
    }
    v.iter()
        .enumerate()
        .map(|(i, &vi)| vi * mean_service.get(&NodeId(i)).copied().unwrap_or(0.0))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;

    #[test]
    fn profile_estimates_service_means() {
        let g = apps::vanilla_rag();
        let p = profile_graph(&g, 500, 42);
        let retr = g.node_by_name("retriever").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        // k_docs ~ U[100,300] → retriever mean ≈ 0.02 + 4e-4*200 = 0.10.
        let mr = p.mean_service[&retr];
        assert!((0.07..0.14).contains(&mr), "retriever mean {mr}");
        let mg = p.mean_service[&gen];
        assert!(mg > 0.0);
        assert_eq!(p.samples, 500);
    }

    #[test]
    fn profile_edge_probs_match_priors() {
        let g = apps::corrective_rag();
        let p = profile_graph(&g, 4000, 7);
        // Find grader→generator edge; empirical prob ≈ 0.7.
        let grader = g.node_by_name("grader").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        let (i, _) = g
            .edges
            .iter()
            .enumerate()
            .find(|(_, e)| e.from == grader && e.to == gen)
            .unwrap();
        let prob = p.edge_probs[i];
        assert!((prob - apps::CRAG_P_RELEVANT).abs() < 0.05, "prob {prob}");
    }

    #[test]
    fn profile_alpha_positive_for_primary_resource() {
        let g = apps::self_rag();
        let p = profile_graph(&g, 300, 3);
        for node in g.work_nodes() {
            let has_alpha = ResourceKind::ALL
                .iter()
                .any(|&k| p.alpha_for(node.id, k) > 0.0);
            assert!(has_alpha, "{} missing alpha", node.name);
        }
    }

    #[test]
    fn cached_retriever_profiles_faster_and_alpha_rises() {
        let plain = apps::vanilla_rag();
        let cached = apps::cached_vanilla_rag(1.2, 0.8, 1024, 4096);
        let pp = profile_graph(&plain, 3000, 11);
        let pc = profile_graph(&cached, 3000, 11);
        let rp = plain.node_by_name("retriever").unwrap();
        let rc = cached.node_by_name("retriever").unwrap();
        let h = rc.cache_hit_rate;
        assert!(h > 0.3, "workload should produce a real hit rate, got {h}");
        let expect = crate::profile::models::cache_service_factor(h);
        let ratio = pc.mean_service[&rc.id] / pp.mean_service[&rp.id];
        // Sampled hit draws converge to the closed-form factor.
        assert!(
            (ratio - expect).abs() < 0.08,
            "mean-service ratio {ratio} vs cache factor {expect}"
        );
        // Cache-adjusted α: the LP sees more throughput per CPU unit.
        let k = crate::spec::ResourceKind::Cpu;
        assert!(pc.alpha_for(rc.id, k) > pp.alpha_for(rp.id, k));
    }

    #[test]
    fn legacy_mode_profile_is_bit_identical_to_plain_profile() {
        // `profile_graph` must stay byte-for-byte what it was: the
        // explicit-Legacy path consumes the same rng stream.
        let g = apps::corrective_rag();
        let a = profile_graph(&g, 400, 17);
        let b = profile_graph_gen(&g, 400, 17, crate::profile::models::GenBatching::Legacy);
        for n in &g.nodes {
            assert_eq!(a.mean_service[&n.id].to_bits(), b.mean_service[&n.id].to_bits());
        }
        for (pa, pb) in a.edge_probs.iter().zip(&b.edge_probs) {
            assert_eq!(pa.to_bits(), pb.to_bits());
        }
    }

    #[test]
    fn static_batching_prior_dominates_continuous_which_tracks_legacy() {
        // The mispricing the tentpole fixes, visible in the priors: the
        // static run-to-completion model inflates generator service by
        // the expected batch-max decode count, while continuous batching
        // prices only the request's own steps (≈ the legacy aggregate at
        // its occupancy). The LP and admission slack inherit these means.
        use crate::profile::models::GenBatching;
        let g = apps::vanilla_rag();
        let gen = g.node_by_name("generator").unwrap().id;
        let leg = profile_graph_gen(&g, 3000, 23, GenBatching::Legacy).mean_service[&gen];
        let sta = profile_graph_gen(&g, 3000, 23, GenBatching::Static).mean_service[&gen];
        let con = profile_graph_gen(&g, 3000, 23, GenBatching::Continuous).mean_service[&gen];
        assert!(
            sta > 1.3 * con,
            "static prior {sta} must dominate continuous {con} (batch-max inflation)"
        );
        // Continuous at steady occupancy = legacy mean × the occupancy
        // step premium (≤ ~18% at B=4) — same order, never inflated by
        // a co-batched neighbor's length.
        assert!(con < 1.3 * leg && con > 0.9 * leg, "continuous {con} vs legacy {leg}");
        // Retriever (not a generator) is untouched by the knob.
        let retr = g.node_by_name("retriever").unwrap().id;
        let a = profile_graph_gen(&g, 500, 29, GenBatching::Legacy).mean_service[&retr];
        let b = profile_graph_gen(&g, 500, 29, GenBatching::Continuous).mean_service[&retr];
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn fork_branches_profile_at_full_flow_with_unit_edge_probs() {
        let g = apps::hybrid_rag();
        let p = profile_graph(&g, 600, 13);
        // Every branch node sampled once per request.
        for name in ["retriever", "websearch", "generator"] {
            let id = g.node_by_name(name).unwrap().id;
            assert!(p.mean_service[&id] > 0.0, "{name} unprofiled");
        }
        // Fork edges report empirical probability 1 — full flow per
        // branch, which is what the LP's conservation rows consume.
        for (i, e) in g.edges.iter().enumerate() {
            if e.is_fork() {
                assert!(
                    (p.edge_probs[i] - 1.0).abs() < 1e-12,
                    "fork edge prob {}",
                    p.edge_probs[i]
                );
            }
        }
        // Multi-query: every variant branch is walked (gets real means).
        let mq = apps::multiquery_rag(3);
        let pm = profile_graph(&mq, 300, 13);
        for i in 0..3 {
            let id = mq.node_by_name(&format!("retriever_q{i}")).unwrap().id;
            assert!(pm.mean_service[&id] > 0.0, "variant {i} unprofiled");
        }
    }

    #[test]
    fn graph_latency_is_critical_path_not_branch_sum() {
        // Hybrid vs its serialized control, same node means: the
        // parallel estimate must equal serial minus the overlapped
        // (faster) branch — max(retr, web) instead of retr + web.
        let par = apps::hybrid_rag();
        let seq = apps::hybrid_rag_sequential();
        let means = |g: &crate::spec::PipelineGraph| -> HashMap<NodeId, f64> {
            g.nodes
                .iter()
                .map(|n| {
                    let m = match n.name.as_str() {
                        "retriever" => 0.10,
                        "websearch" => 0.15,
                        "generator" => 0.10,
                        _ => 0.0,
                    };
                    (n.id, m)
                })
                .collect()
        };
        let lp = graph_latency(&par, &means(&par));
        let ls = graph_latency(&seq, &means(&seq));
        assert!((ls - 0.35).abs() < 1e-9, "serial sum {ls}");
        assert!((lp - 0.25).abs() < 1e-9, "parallel critical path {lp}");
        // Fork-free graphs: identical to the visit-weighted sum.
        let g = apps::corrective_rag();
        let p = profile_graph(&g, 800, 3);
        let direct: f64 = g
            .visit_rates()
            .iter()
            .enumerate()
            .map(|(i, v)| v * p.mean_service.get(&NodeId(i)).copied().unwrap_or(0.0))
            .sum();
        let cp = graph_latency(&g, &p.mean_service);
        assert!((cp - direct).abs() < 1e-9, "{cp} vs {direct}");
    }

    #[test]
    fn gen_split_partitions_the_generator_mean_exactly() {
        // The split is an exact decomposition of the same samples:
        // prefill + decode == mean_service for every generator node, in
        // every batching mode, and non-generators get no split entry.
        use crate::profile::models::GenBatching;
        let g = apps::vanilla_rag();
        let gen = g.node_by_name("generator").unwrap().id;
        let retr = g.node_by_name("retriever").unwrap().id;
        for mode in [GenBatching::Legacy, GenBatching::Static, GenBatching::Continuous] {
            let p = profile_graph_gen(&g, 2000, 31, mode);
            let s = p.gen_split[&gen];
            assert!(
                (s.total() - p.mean_service[&gen]).abs() < 1e-9,
                "{mode:?}: split {} + {} vs mean {}",
                s.prefill,
                s.decode,
                p.mean_service[&gen]
            );
            // Decode dominates at the trace's token mix (~40 decode steps
            // at 2 ms vs a ~60-token prefill at 0.1 ms/tok).
            assert!(s.decode > 2.0 * s.prefill, "{mode:?}: {s:?}");
            // Prompt-token mean sits inside the trace clamp [4, 127].
            assert!((4.0..=127.0).contains(&s.prompt_tokens), "{mode:?}: {s:?}");
            assert!(!p.gen_split.contains_key(&retr));
        }
    }

    #[test]
    fn placement_priors_collocated_identity_and_disagg_reprice() {
        use crate::profile::models::{GenPlacement, KvTransferModel};
        let g = apps::corrective_rag();
        let p = profile_graph(&g, 2000, 37);
        let kv = KvTransferModel::paper_interconnect();
        // Collocated: bit-identical to the plain priors (the knob is
        // inert by default, like GenBatching::Legacy).
        let col = p.placement_priors(GenPlacement::Collocated, &kv, 0.0);
        for (id, m) in &p.mean_service {
            assert_eq!(m.to_bits(), col[id].to_bits());
        }
        // Disaggregated, no prefix cache: generator prior = split total +
        // KV handoff (a small, strictly positive premium); everything
        // else untouched.
        let dis = p.placement_priors(GenPlacement::Disaggregated, &kv, 0.0);
        let gen = g.node_by_name("generator").unwrap().id;
        let grader = g.node_by_name("grader").unwrap().id;
        assert!(dis[&gen] > p.mean_service[&gen]);
        assert!(dis[&gen] < p.mean_service[&gen] + 0.01, "handoff must be small: {}", dis[&gen]);
        assert_eq!(dis[&grader].to_bits(), p.mean_service[&grader].to_bits());
        // A hot prefix cache discounts the prefill share: the prior falls
        // below the collocated aggregate once the saved prefill exceeds
        // the transfer cost.
        let hot = p.placement_priors(GenPlacement::Disaggregated, &kv, 0.9);
        assert!(hot[&gen] < dis[&gen]);
        let s = p.gen_split[&gen];
        let saved = s.prefill * 0.9 * (1.0 - crate::profile::models::KV_PREFIX_HIT_COST_FRAC);
        assert!(
            (dis[&gen] - hot[&gen] - saved).abs() < 1e-9,
            "cache discount {} vs expected {saved}",
            dis[&gen] - hot[&gen]
        );
    }

    #[test]
    fn profile_deterministic_for_seed() {
        let g = apps::adaptive_rag();
        let a = profile_graph(&g, 200, 9);
        let b = profile_graph(&g, 200, 9);
        for n in &g.nodes {
            assert_eq!(a.mean_service[&n.id], b.mean_service[&n.id]);
        }
    }
}
