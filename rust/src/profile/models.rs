//! Component latency models — the calibrated stand-in for the paper's
//! hardware testbed (4 nodes × 8 A100).
//!
//! Each [`ComponentKind`] gets a service-time model of the form
//!
//! `t = (base + c_k·k_docs + c_p·prompt_len + c_g·gen_len) · lognormal(σ)`
//!
//! with coefficients chosen to reproduce the paper's *relative* component
//! costs (the quantities its coordination results depend on):
//!
//! * V-RAG: retriever ≈ generator (Fig. 3 "naturally balanced", §4.1);
//! * C-RAG: grader ≈ 1.8 × generator (§4.3 allocation plans);
//! * S-RAG: critic ≪ generator — single-token verdict (§4.3);
//! * A-RAG: classifier is the bottleneck (§4.3).
//!
//! The live path (real XLA artifacts) has different absolute numbers; the
//! profiler (`profiler.rs`) re-estimates α from whichever substrate it
//! runs against, so policies never hardcode these values.

use crate::sched::degrade::OverloadLevel;
use crate::spec::graph::{ComponentKind, DegradeKnob};
use crate::util::rng::Rng;

/// Per-request workload features, sampled at admission (workload layer)
/// and observed by the telemetry/slack predictors.
#[derive(Clone, Copy, Debug)]
pub struct RequestFeatures {
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Target generation length in tokens.
    pub gen_len: usize,
    /// Number of documents retrieved (paper: uniform in [100, 300]).
    pub k_docs: usize,
    /// Query complexity class (A-RAG): 0 simple, 1 standard, 2 complex.
    pub complexity: u8,
}

impl RequestFeatures {
    /// Feature vector for the slack regressors (§3.3.2).
    pub fn vector(&self) -> [f64; 3] {
        [self.prompt_len as f64, self.gen_len as f64, self.k_docs as f64]
    }
}

/// Linear-in-features service time with multiplicative lognormal noise.
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    pub base: f64,
    pub per_doc: f64,
    pub per_prompt_tok: f64,
    pub per_gen_tok: f64,
    pub sigma: f64,
}

impl LatencyModel {
    /// Mean service time for given features (noise-free).
    pub fn mean(&self, f: &RequestFeatures) -> f64 {
        self.base
            + self.per_doc * f.k_docs as f64
            + self.per_prompt_tok * f.prompt_len as f64
            + self.per_gen_tok * f.gen_len as f64
    }

    /// Sampled service time.
    pub fn sample(&self, f: &RequestFeatures, rng: &mut Rng) -> f64 {
        (self.mean(f) * self.noise(rng)).max(1e-6)
    }

    /// One unit-mean multiplicative noise draw (`exp(N(-σ²/2, σ))`) —
    /// the same lognormal `sample` applies, exposed so the decomposed
    /// decode cost model shares this model's variance.
    pub fn noise(&self, rng: &mut Rng) -> f64 {
        rng.lognormal(-self.sigma * self.sigma / 2.0, self.sigma)
    }

    /// The calibrated model for a component kind.
    pub fn for_kind(kind: &ComponentKind) -> LatencyModel {
        match kind {
            ComponentKind::Source | ComponentKind::Sink => LatencyModel {
                base: 0.0,
                per_doc: 0.0,
                per_prompt_tok: 0.0,
                per_gen_tok: 0.0,
                sigma: 0.0,
            },
            // CPU/memory-bound nearest-neighbor search; scales with k.
            ComponentKind::Retriever => LatencyModel {
                base: 0.02,
                per_doc: 4.0e-4,
                per_prompt_tok: 0.0,
                per_gen_tok: 0.0,
                sigma: 0.25,
            },
            // GPU decode: prefill ∝ prompt+context, decode ∝ output tokens.
            ComponentKind::Generator => LatencyModel {
                base: 0.01,
                per_doc: 0.0,
                per_prompt_tok: 1.0e-4,
                per_gen_tok: 2.0e-3,
                sigma: 0.30,
            },
            // Single-token relevance verdict over all retrieved docs:
            // prefill-heavy, scales with k (C-RAG's bottleneck).
            ComponentKind::Grader => LatencyModel {
                base: 0.02,
                per_doc: 8.0e-4,
                per_prompt_tok: 0.0,
                per_gen_tok: 0.0,
                sigma: 0.25,
            },
            // Single-token verdict over the generated answer only.
            ComponentKind::Critic => LatencyModel {
                base: 0.015,
                per_doc: 0.0,
                per_prompt_tok: 0.0,
                per_gen_tok: 1.0e-4,
                sigma: 0.20,
            },
            // Short rewrite generation.
            ComponentKind::Rewriter => LatencyModel {
                base: 0.012,
                per_doc: 0.0,
                per_prompt_tok: 1.0e-4,
                per_gen_tok: 0.0,
                sigma: 0.25,
            },
            // External I/O: high base, heavy tail.
            ComponentKind::WebSearch => LatencyModel {
                base: 0.15,
                per_doc: 0.0,
                per_prompt_tok: 0.0,
                per_gen_tok: 0.0,
                sigma: 0.50,
            },
            // Query-complexity classifier (A-RAG's bottleneck: every
            // request passes through it).
            ComponentKind::Classifier => LatencyModel {
                base: 0.11,
                per_doc: 0.0,
                per_prompt_tok: 5.0e-5,
                per_gen_tok: 0.0,
                sigma: 0.15,
            },
            ComponentKind::Custom(_) => LatencyModel {
                base: 0.05,
                per_doc: 0.0,
                per_prompt_tok: 0.0,
                per_gen_tok: 0.0,
                sigma: 0.25,
            },
        }
    }
}

/// Serial fraction of a scatter-gather retrieval request that does not
/// shrink with the shard count (embedding the query, dispatching the
/// fan-out, assembling the response). Modeled at 5%; the
/// `fig04b_shard_scaling` bench is the calibration target — re-fit this
/// constant to its measured curve when the bench is run on real
/// hardware (see EXPERIMENTS.md).
pub const SHARD_SERIAL_FRAC: f64 = 0.05;

/// Per-extra-shard merge/coordination overhead as a fraction of the
/// unsharded service time: each additional shard contributes one more
/// sorted top-k list to the k-way gather merge plus one more fan-out
/// message.
pub const SHARD_MERGE_FRAC: f64 = 0.01;

/// Calibrated shard latency model: service-time multiplier for a
/// component whose data is partitioned across `shards` partitions probed
/// in parallel (retrieval scatter-gather). Amdahl-style:
///
/// `factor(S) = serial + (1 - serial)/S + merge·(S - 1)`
///
/// `factor(1) == 1.0` exactly, so unsharded components are untouched;
/// speedup is sublinear and eventually reverses (merge overhead grows
/// with S) — the shape `benches/fig04b_shard_scaling` exists to measure
/// (re-fit the constants from its output; they are modeled, not yet
/// measured). Applied consistently by the deploy-time profiler and the
/// DES, so LP priors and simulated telemetry agree.
pub fn shard_service_factor(shards: usize) -> f64 {
    if shards <= 1 {
        return 1.0; // exact identity: unsharded latencies are untouched
    }
    let s = shards as f64;
    SHARD_SERIAL_FRAC + (1.0 - SHARD_SERIAL_FRAC) / s + SHARD_MERGE_FRAC * (s - 1.0)
}

/// Cost of a request-cache hit relative to a full retrieval pass:
/// normalize + hash probe (exact tier) or one dot-product scan (semantic
/// tier) plus context assembly, against an embed + scatter-gather +
/// merge. Modeled at 5%; `benches/fig04c_cache_hit_curve.rs` is the
/// calibration target — re-fit from its measured hit/miss latencies.
pub const CACHE_HIT_COST_FRAC: f64 = 0.05;

/// Cache-adjusted mean service-time multiplier for a component with
/// expected hit rate `h`:
///
/// `factor(h) = (1 - h) + h · CACHE_HIT_COST_FRAC`
///
/// `factor(0) == 1.0` exactly, so uncached components are untouched.
/// The DES samples per-request hits instead of applying the mean (the
/// latency distribution is bimodal — that is what moves p50 at high hit
/// rates); this closed form is what the profiler's α estimate and the
/// allocation LP converge to over many samples, keeping deploy-time
/// priors and simulated telemetry consistent.
pub fn cache_service_factor(hit_rate: f64) -> f64 {
    let h = hit_rate.clamp(0.0, 1.0);
    1.0 - h * (1.0 - CACHE_HIT_COST_FRAC)
}

/// Service-time multiplier for SQ8-quantized retrieval
/// ([`crate::retrieval::Quantization::SQ8`]) relative to the f32 scan.
/// The candidate scan streams 1 byte/dim instead of 4 (memory-bandwidth
/// bound → ~4× faster), but centroid scoring, the probe sort, and the
/// exact rescoring pass over `rerank_factor × k` survivors stay at f32,
/// so the end-to-end retrieval service time lands well above 0.25×.
/// Modeled at 0.45; `benches/perf_retrieval.rs` is the calibration
/// target — re-fit from its measured f32 vs SQ8 per-query p50 once the
/// bench has run on real hardware (see EXPERIMENTS.md).
pub const QUANTIZED_SERVICE_FRAC: f64 = 0.45;

/// Quantization-adjusted service-time multiplier for a retrieval
/// component. `factor(false) == 1.0` exactly — unquantized deployments
/// (the default) are untouched, which is what keeps the golden traces
/// bit-identical. Applied consistently by the deploy-time profiler and
/// the DES, so LP priors and simulated telemetry agree.
pub fn quantized_service_factor(quantized: bool) -> f64 {
    if quantized {
        QUANTIZED_SERVICE_FRAC
    } else {
        1.0
    }
}

/// Steady-state hit-rate estimate for a Zipf(s) repeat-query workload
/// (`workload::queries::QueryMix`): a `repeat_frac` fraction of requests
/// re-draw from a pool of `pool` known queries with rank popularity
/// ∝ 1/rank^s, and an LRU/LFU cache of `cache_entries` entries holds the
/// hottest ranks, so
///
/// `hit ≈ repeat_frac · H(min(cache, pool), s) / H(pool, s)`
///
/// with `H(n, s) = Σ_{i=1..n} i^{-s}` the generalized harmonic number.
/// Cold (first-touch) misses are ignored — this is the long-run rate.
/// Monotone in `s`, `repeat_frac`, and `cache_entries`; use it to set
/// `NodeSpec::cache_hit_rate` from workload knobs.
pub fn zipf_hit_rate(zipf_s: f64, repeat_frac: f64, pool: usize, cache_entries: usize) -> f64 {
    if pool == 0 || cache_entries == 0 {
        return 0.0;
    }
    let harmonic = |n: usize| -> f64 { (1..=n).map(|i| (i as f64).powf(-zipf_s)).sum::<f64>() };
    let covered = harmonic(cache_entries.min(pool)) / harmonic(pool);
    (repeat_frac.clamp(0.0, 1.0) * covered).clamp(0.0, 1.0)
}

/// Cost of a skipped optional hop (grader/rerank bypassed at severe
/// overload) relative to the full pass: the stage still receives and
/// forwards the request (one dispatch + a constant-time pass-through
/// verdict), but runs no model. Same order as a cache hit.
pub const DEGRADE_SKIP_COST_FRAC: f64 = 0.05;

/// Service-time multiplier for a component with degradation knob `knob`
/// under overload `level` — the DES counterpart of what live workers do
/// (shrink top-k / skip the hop). Calibrated against the latency models
/// above:
///
/// * `ShrinkTopK`: retrieval-style stages are `base + per_doc·k`; halving
///   k (Elevated) removes ~half the k-term → ≈0.75 of the mean at
///   k ∈ [100, 300]; quartering (Severe) → ≈0.6.
/// * `SkipHop`: full cost until `Severe`, then the pass-through cost.
/// * `CapIterations`: per-visit cost is unchanged (the knob cuts the
///   *number* of loop visits, applied at branch-sampling time).
///
/// Exactly 1.0 whenever `level == Normal` or `knob == None`, so runs
/// with degradation disabled are bit-identical to pre-degradation runs.
pub fn degrade_service_factor(knob: DegradeKnob, level: OverloadLevel) -> f64 {
    match (knob, level) {
        (DegradeKnob::None, _) | (_, OverloadLevel::Normal) => 1.0,
        (DegradeKnob::ShrinkTopK, OverloadLevel::Elevated) => 0.75,
        (DegradeKnob::ShrinkTopK, OverloadLevel::Severe) => 0.6,
        (DegradeKnob::SkipHop, OverloadLevel::Elevated) => 1.0,
        (DegradeKnob::SkipHop, OverloadLevel::Severe) => DEGRADE_SKIP_COST_FRAC,
        (DegradeKnob::CapIterations, _) => 1.0,
    }
}

/// How the generator schedules co-resident requests onto its decode
/// slots — the batching-policy knob threaded through `SimConfig` (DES)
/// and `ControllerConfig` (live path).
///
/// * [`GenBatching::Legacy`] — the pre-batching aggregate latency model
///   (`LatencyModel::for_kind(Generator)` sampled per visit). The
///   default for the DES: fixed-seed golden traces replay bit-identically.
/// * [`GenBatching::Static`] — run-to-completion batches modeled
///   explicitly at decode-step granularity: a batch admits up to `B`
///   requests together, decodes `max(gen_len)` steps, and every member —
///   including a short answer co-batched with a long one — finishes when
///   the longest does. This is what the live generator's
///   `generate_batch` loop actually did, and what the profiler/LP/
///   autoscaler previously mispriced.
/// * [`GenBatching::Continuous`] — iteration-level (vLLM/Orca-style)
///   batching: requests join a free slot between decode steps
///   (prefill-on-join) and retire the step they emit EOS or hit their
///   token cap, paying `prefill + own_steps × step(occupancy)`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GenBatching {
    /// Aggregate calibrated model (golden-trace default for the DES).
    #[default]
    Legacy,
    /// Explicit run-to-completion batches (the static fallback knob).
    Static,
    /// Iteration-level continuous batching (the live-path default).
    Continuous,
}

/// Where the generator's prefill and decode phases run — the RAGO-style
/// task-placement knob threaded through `SimConfig` (DES), the allocation
/// LP (`alloc::FlowProblem::with_placement`), and the live controller.
///
/// * [`GenPlacement::Collocated`] — one pool serves both phases (the
///   pre-split behavior and the default: fixed-seed golden traces replay
///   bit-identically).
/// * [`GenPlacement::Disaggregated`] — prefill and decode run on separate
///   pools; a finished prefill hands its KV cache to a decode instance,
///   paying [`KvTransferModel::cost`] on the way. Each pool gets its own
///   LP columns and autoscaling α, so a decode-bound workload buys decode
///   capacity instead of over-provisioning monolithic replicas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GenPlacement {
    /// One pool runs prefill + decode back-to-back (golden-trace default).
    #[default]
    Collocated,
    /// Separate prefill/decode pools with explicit KV handoff.
    Disaggregated,
}

/// Cost of shipping a finished prefill's KV cache to a decode instance:
/// a fixed handshake plus a per-token payload term. The `scale` knob is
/// the experiment axis — 1.0 models the paper testbed's NVLink-class
/// interconnect; inflating it (slow fabric, cross-node hop) is how the
/// "collocated wins" regime is reached, and the LP sees the same term so
/// it can refuse the split when transfer dominates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvTransferModel {
    /// Fixed per-handoff cost (seconds): connection + metadata handshake.
    pub base: f64,
    /// Per-token payload cost (seconds/token of prefilled context).
    pub per_tok: f64,
    /// Interconnect multiplier (1.0 = paper testbed; larger = slower).
    pub scale: f64,
}

impl Default for KvTransferModel {
    fn default() -> Self {
        KvTransferModel::paper_interconnect()
    }
}

impl KvTransferModel {
    /// The calibrated testbed interconnect: ~0.5 ms handshake + 5 µs per
    /// prefilled token — a 64-token prompt hands off in ~0.8 ms, well
    /// under one decode step, so disaggregation is near-free on the
    /// reference fabric.
    pub fn paper_interconnect() -> KvTransferModel {
        KvTransferModel { base: 5.0e-4, per_tok: 5.0e-6, scale: 1.0 }
    }

    /// Deterministic transfer cost for a KV cache of `tokens` prefilled
    /// tokens (no noise: the payload size is known exactly).
    pub fn cost(&self, tokens: usize) -> f64 {
        self.scale * (self.base + self.per_tok * tokens as f64)
    }
}

/// Cost of a KV-prefix-cache hit relative to a full prefill: the cached
/// segment chain's KV blocks are remapped instead of recomputed, leaving
/// only attention over the (short) uncached tail. Modeled at 15% — higher
/// than a retrieval-cache hit because the generator still runs its
/// prologue and must attend across the restored blocks.
pub const KV_PREFIX_HIT_COST_FRAC: f64 = 0.15;

/// Prefill service-time multiplier for a generator pool whose KV prefix
/// cache hits a `h` fraction of requests:
///
/// `factor(h) = (1 - h) + h · KV_PREFIX_HIT_COST_FRAC`
///
/// `factor(0) == 1.0` exactly, so runs without the prefix cache are
/// untouched. Same closed-form-vs-sampled split as
/// [`cache_service_factor`]: the DES draws per-request hits, the
/// profiler/LP apply the mean.
pub fn kv_prefix_service_factor(hit_rate: f64) -> f64 {
    let h = hit_rate.clamp(0.0, 1.0);
    1.0 - h * (1.0 - KV_PREFIX_HIT_COST_FRAC)
}

/// Occupancy-aware decode cost model (the tentpole's pricing function):
///
/// `service = prefill(prompt_tokens) + steps × step(batch_occupancy)`
///
/// where `steps` is the request's *own* decode count under continuous
/// batching and the *batch maximum* under static batching. Consumed by
/// the DES (`sim::simrun`), the profiler (so LP priors and the
/// autoscaler's α targets are batching-aware), and — through the
/// profiled `mean_service` priors seeding `sched::SlackPredictor` — the
/// admission controller's slack predictions. One pricing function, three
/// consumers: the simulator, the allocator, and the live data plane
/// agree on what a batched decode step costs.
#[derive(Clone, Copy, Debug)]
pub struct DecodeCostModel {
    /// Fixed prefill overhead (kernel launch, KV allocation).
    pub prefill_base: f64,
    /// Prefill cost per prompt token (parallel over tokens, cheap).
    pub prefill_per_tok: f64,
    /// One decode step with a single resident request.
    pub step_base: f64,
    /// Relative per-step slowdown per additional co-resident request
    /// (memory-bandwidth sharing; the occupancy term).
    pub step_per_occupant: f64,
}

impl DecodeCostModel {
    /// The calibrated generator model. At occupancy 1 this reproduces
    /// `LatencyModel::for_kind(Generator)`'s mean exactly
    /// (`base + per_prompt_tok·p + per_gen_tok·g`), so the decomposed
    /// model and the legacy aggregate agree on an unbatched request; the
    /// occupancy slope mirrors [`concurrency_slowdown`] (6% per extra
    /// occupant), which it replaces for stepped generators.
    pub fn generator() -> DecodeCostModel {
        DecodeCostModel {
            prefill_base: 0.01,
            prefill_per_tok: 1.0e-4,
            step_base: 2.0e-3,
            step_per_occupant: 0.06,
        }
    }

    /// Prefill cost for a prompt of `tokens` tokens.
    pub fn prefill(&self, tokens: usize) -> f64 {
        self.prefill_base + self.prefill_per_tok * tokens as f64
    }

    /// One decode step with `occupancy` co-resident requests (≥ 1).
    pub fn step(&self, occupancy: usize) -> f64 {
        self.step_base * (1.0 + self.step_per_occupant * occupancy.saturating_sub(1) as f64)
    }

    /// Continuous batching: the request pays its own decode steps at the
    /// occupancy-dependent step cost, independent of its neighbors'
    /// lengths.
    pub fn continuous(&self, f: &RequestFeatures, occupancy: usize) -> f64 {
        self.prefill(f.prompt_len) + f.gen_len as f64 * self.step(occupancy)
    }

    /// Static run-to-completion batching: every member of a `batch_size`
    /// batch decodes for the batch's maximum step count — a short answer
    /// co-batched with a long one pays the long one's decode length.
    pub fn static_batch(
        &self,
        f: &RequestFeatures,
        batch_max_steps: usize,
        batch_size: usize,
    ) -> f64 {
        self.prefill(f.prompt_len) + batch_max_steps as f64 * self.step(batch_size)
    }
}

/// GPU components serve several requests concurrently (continuous
/// batching); effective concurrency per instance.
pub fn instance_concurrency(kind: &ComponentKind) -> usize {
    match kind {
        ComponentKind::Generator | ComponentKind::Grader | ComponentKind::Critic
        | ComponentKind::Rewriter => 4,
        ComponentKind::Classifier => 8,
        // An 8-core retriever instance runs one search per core.
        ComponentKind::Retriever => 8,
        ComponentKind::WebSearch => 16,
        _ => 1,
    }
}

/// Mild per-slot slowdown when an instance runs near its concurrency
/// limit (batching is not free).
pub fn concurrency_slowdown(active: usize) -> f64 {
    1.0 + 0.06 * active.saturating_sub(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feats() -> RequestFeatures {
        RequestFeatures { prompt_len: 60, gen_len: 45, k_docs: 200, complexity: 1 }
    }

    #[test]
    fn crag_grader_ratio_matches_paper() {
        // §4.3: grader ≈ 1.8× generator runtime.
        let f = feats();
        let grader = LatencyModel::for_kind(&ComponentKind::Grader).mean(&f);
        let genr = LatencyModel::for_kind(&ComponentKind::Generator).mean(&f);
        let ratio = grader / genr;
        assert!((1.5..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn vrag_is_balanced() {
        let f = feats();
        let retr = LatencyModel::for_kind(&ComponentKind::Retriever).mean(&f);
        let genr = LatencyModel::for_kind(&ComponentKind::Generator).mean(&f);
        let ratio = retr / genr;
        assert!((0.7..1.3).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn critic_much_cheaper_than_generator() {
        let f = feats();
        let critic = LatencyModel::for_kind(&ComponentKind::Critic).mean(&f);
        let genr = LatencyModel::for_kind(&ComponentKind::Generator).mean(&f);
        assert!(critic < 0.3 * genr, "critic {critic} vs gen {genr}");
    }

    #[test]
    fn classifier_dominates_arag_per_visit_cost() {
        let f = feats();
        let cls = LatencyModel::for_kind(&ComponentKind::Classifier).mean(&f);
        let genr = LatencyModel::for_kind(&ComponentKind::Generator).mean(&f);
        assert!(cls > genr, "classifier {cls} vs generator {genr}");
    }

    #[test]
    fn sample_noise_has_unit_mean() {
        let m = LatencyModel::for_kind(&ComponentKind::Generator);
        let f = feats();
        let mut rng = Rng::new(0);
        let n = 50_000;
        let avg: f64 = (0..n).map(|_| m.sample(&f, &mut rng)).sum::<f64>() / n as f64;
        let rel = (avg - m.mean(&f)).abs() / m.mean(&f);
        assert!(rel < 0.02, "rel err {rel}");
    }

    #[test]
    fn sample_is_positive() {
        let m = LatencyModel::for_kind(&ComponentKind::WebSearch);
        let f = feats();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(m.sample(&f, &mut rng) > 0.0);
        }
    }

    #[test]
    fn cache_factor_identity_when_uncached() {
        assert_eq!(cache_service_factor(0.0), 1.0);
        // Full hits cost exactly the hit fraction.
        assert!((cache_service_factor(1.0) - CACHE_HIT_COST_FRAC).abs() < 1e-12);
        // Monotone decreasing in the hit rate.
        let mut prev = cache_service_factor(0.0);
        for i in 1..=10 {
            let f = cache_service_factor(i as f64 / 10.0);
            assert!(f < prev, "factor must fall with hit rate: {f} vs {prev}");
            prev = f;
        }
    }

    #[test]
    fn quantized_factor_identity_when_unquantized() {
        // Exact identity at the default: unquantized deployments replay
        // golden traces bit-identically.
        assert_eq!(quantized_service_factor(false), 1.0);
        assert_eq!(quantized_service_factor(true), QUANTIZED_SERVICE_FRAC);
        // A speedup, but not the raw 4× bandwidth win: rescoring and
        // centroid scoring stay f32.
        assert!(QUANTIZED_SERVICE_FRAC < 1.0);
        assert!(QUANTIZED_SERVICE_FRAC > 0.25);
    }

    #[test]
    fn zipf_hit_rate_monotone_in_skew_and_capacity() {
        // More skew → hotter head → more of the mass fits in the cache.
        let pool = 4096;
        let cache = 256;
        let mut prev = 0.0;
        for s in [0.4, 0.8, 1.2, 1.6] {
            let h = zipf_hit_rate(s, 0.8, pool, cache);
            assert!(h > prev, "hit rate must grow with zipf_s: {h} vs {prev}");
            assert!((0.0..1.0).contains(&h));
            prev = h;
        }
        // Bigger cache → more hits, saturating at repeat_frac.
        let mut prev = 0.0;
        for c in [16, 64, 256, 1024, 4096] {
            let h = zipf_hit_rate(1.1, 0.8, pool, c);
            assert!(h >= prev);
            prev = h;
        }
        assert!((zipf_hit_rate(1.1, 0.8, pool, pool) - 0.8).abs() < 1e-12);
        // Degenerate inputs.
        assert_eq!(zipf_hit_rate(1.0, 0.8, 0, 64), 0.0);
        assert_eq!(zipf_hit_rate(1.0, 0.8, 1024, 0), 0.0);
        assert_eq!(zipf_hit_rate(1.0, 0.0, 1024, 64), 0.0);
    }

    #[test]
    fn degrade_factor_identity_when_normal_or_unannotated() {
        for knob in [
            DegradeKnob::None,
            DegradeKnob::ShrinkTopK,
            DegradeKnob::SkipHop,
            DegradeKnob::CapIterations,
        ] {
            assert_eq!(degrade_service_factor(knob, OverloadLevel::Normal), 1.0, "{knob:?}");
        }
        for level in [OverloadLevel::Normal, OverloadLevel::Elevated, OverloadLevel::Severe] {
            assert_eq!(degrade_service_factor(DegradeKnob::None, level), 1.0, "{level:?}");
        }
        // The ladder is monotone: more overload, less work per visit.
        let shrink = |l| degrade_service_factor(DegradeKnob::ShrinkTopK, l);
        assert!(shrink(OverloadLevel::Severe) < shrink(OverloadLevel::Elevated));
        assert!(shrink(OverloadLevel::Elevated) < shrink(OverloadLevel::Normal));
        // SkipHop collapses to the pass-through cost only at Severe.
        assert_eq!(degrade_service_factor(DegradeKnob::SkipHop, OverloadLevel::Elevated), 1.0);
        assert_eq!(
            degrade_service_factor(DegradeKnob::SkipHop, OverloadLevel::Severe),
            DEGRADE_SKIP_COST_FRAC
        );
        // CapIterations never changes per-visit cost.
        assert_eq!(degrade_service_factor(DegradeKnob::CapIterations, OverloadLevel::Severe), 1.0);
    }

    #[test]
    fn decode_model_matches_legacy_aggregate_at_occupancy_one() {
        // The decomposed prefill+decode model and the calibrated
        // aggregate must agree on an unbatched request — that identity is
        // what lets the Continuous DES mode share the legacy bands.
        let dcm = DecodeCostModel::generator();
        let legacy = LatencyModel::for_kind(&ComponentKind::Generator);
        for f in [
            feats(),
            RequestFeatures { prompt_len: 4, gen_len: 96, k_docs: 100, complexity: 0 },
            RequestFeatures { prompt_len: 127, gen_len: 4, k_docs: 300, complexity: 2 },
        ] {
            let a = dcm.continuous(&f, 1);
            let b = legacy.mean(&f);
            assert!((a - b).abs() < 1e-12, "continuous@1 {a} vs legacy mean {b}");
        }
    }

    #[test]
    fn short_request_cobatched_with_long_pays_more_under_static() {
        // The economics the tentpole fixes: a short answer co-batched
        // with a long one waits for the longest decode under static
        // batching, but retires at its own EOS under continuous batching.
        let dcm = DecodeCostModel::generator();
        let short = RequestFeatures { prompt_len: 60, gen_len: 8, k_docs: 200, complexity: 1 };
        let long_steps = 96;
        let static_t = dcm.static_batch(&short, long_steps, 2);
        let cont_t = dcm.continuous(&short, 2);
        assert!(
            static_t > 2.0 * cont_t,
            "static co-batch {static_t} must dominate continuous {cont_t}"
        );
        // A request that IS the longest pays the same decode count either
        // way (occupancy equal): static adds nothing beyond step pricing.
        let long =
            RequestFeatures { prompt_len: 60, gen_len: long_steps, k_docs: 200, complexity: 1 };
        let a = dcm.static_batch(&long, long_steps, 2);
        let b = dcm.continuous(&long, 2);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn decode_step_cost_monotone_in_occupancy() {
        let dcm = DecodeCostModel::generator();
        let mut prev = 0.0;
        for occ in 1..=8 {
            let s = dcm.step(occ);
            assert!(s > prev, "step cost must grow with occupancy: {s} vs {prev}");
            prev = s;
        }
        // Throughput still wins: 8 co-resident requests decode 8 tokens
        // per step at < 8× the solo step cost (the batching dividend).
        assert!(dcm.step(8) < 8.0 * dcm.step(1));
    }

    #[test]
    fn gen_batching_defaults_to_legacy() {
        // The inert default is what keeps golden traces bit-identical.
        assert_eq!(GenBatching::default(), GenBatching::Legacy);
    }

    #[test]
    fn gen_placement_defaults_to_collocated() {
        // Same discipline as the batching knob: the split is opt-in, and
        // the default keeps golden traces bit-identical.
        assert_eq!(GenPlacement::default(), GenPlacement::Collocated);
        assert_eq!(KvTransferModel::default(), KvTransferModel::paper_interconnect());
    }

    #[test]
    fn kv_transfer_cost_scales_linearly() {
        let m = KvTransferModel::paper_interconnect();
        // Base handshake with an empty payload.
        assert!((m.cost(0) - m.base).abs() < 1e-15);
        // 64-token prompt hands off well under one decode step on the
        // reference fabric — disaggregation is near-free there.
        assert!(m.cost(64) < DecodeCostModel::generator().step(1));
        // The scale knob multiplies the whole term (the experiment axis).
        let slow = KvTransferModel { scale: 200.0, ..m };
        assert!((slow.cost(64) - 200.0 * m.cost(64)).abs() < 1e-12);
        // Monotone in payload size.
        assert!(m.cost(128) > m.cost(64));
    }

    #[test]
    fn kv_prefix_factor_identity_when_uncached() {
        assert_eq!(kv_prefix_service_factor(0.0), 1.0);
        // Full hits cost exactly the hit fraction.
        assert!((kv_prefix_service_factor(1.0) - KV_PREFIX_HIT_COST_FRAC).abs() < 1e-12);
        // Monotone decreasing, clamped.
        assert!(kv_prefix_service_factor(0.5) < 1.0);
        assert_eq!(kv_prefix_service_factor(-1.0), 1.0);
        // A KV-prefix hit is pricier than a retrieval-cache hit: the
        // generator still attends over the restored blocks.
        assert!(KV_PREFIX_HIT_COST_FRAC > CACHE_HIT_COST_FRAC);
    }

    #[test]
    fn source_sink_are_free() {
        let f = feats();
        assert_eq!(LatencyModel::for_kind(&ComponentKind::Source).mean(&f), 0.0);
        assert_eq!(LatencyModel::for_kind(&ComponentKind::Sink).mean(&f), 0.0);
    }
}
