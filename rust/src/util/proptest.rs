//! Tiny property-testing harness (proptest substitute; proptest is not in
//! the offline crate cache).
//!
//! Usage:
//! ```no_run
//! use harmonia::util::proptest::{property, Gen};
//! property("sum is commutative", 100, |g| {
//!     let a = g.i64(-1000, 1000);
//!     let b = g.i64(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! On failure the macro panics with the failing case number and seed so the
//! case can be replayed deterministically.

use crate::util::rng::Rng;

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Log of generated values, printed on failure for diagnosis.
    log: Vec<String>,
}

impl Gen {
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        let v = self.rng.range_i64(lo, hi);
        self.log.push(format!("i64({lo},{hi})={v}"));
        v
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.i64(lo as i64, hi as i64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform(lo, hi);
        self.log.push(format!("f64({lo},{hi})={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.log.push(format!("bool={v}"));
        v
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.index(xs.len());
        self.log.push(format!("choose idx={i}"));
        &xs[i]
    }

    /// Raw access for structured generation.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `f` against `cases` generated inputs. Panics (with seed + input log)
/// on the first failing case.
pub fn property<F: FnMut(&mut Gen)>(name: &str, cases: usize, f: F) {
    property_seeded(name, cases, 0xC0FFEE, f)
}

pub fn property_seeded<F: FnMut(&mut Gen)>(name: &str, cases: usize, seed: u64, mut f: F) {
    let mut master = Rng::new(seed);
    for case in 0..cases {
        let case_rng = master.fork();
        let mut g = Gen { rng: case_rng, log: Vec::new() };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x})\n  inputs: {}\n  panic: {msg}",
                g.log.join(", "),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        property("add-commutes", 50, |g| {
            let a = g.i64(-100, 100);
            let b = g.i64(-100, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_case() {
        let r = std::panic::catch_unwind(|| {
            property("always-fails-eventually", 50, |g| {
                let v = g.i64(0, 10);
                assert!(v < 10, "hit the max");
            });
        });
        let err = r.expect_err("should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always-fails-eventually"));
        assert!(msg.contains("inputs:"));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut seen = Vec::new();
        property_seeded("record", 5, 42, |g| {
            seen.push(g.i64(0, 1_000_000));
        });
        let mut seen2 = Vec::new();
        property_seeded("record2", 5, 42, |g| {
            seen2.push(g.i64(0, 1_000_000));
        });
        assert_eq!(seen, seen2);
        assert_eq!(seen.len(), 5);
    }
}
