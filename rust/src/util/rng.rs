//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic path in the crate (workload generation, simulation,
//! property tests) takes an explicit [`Rng`] so runs are reproducible from
//! a single seed — required for the DES results in EXPERIMENTS.md to be
//! re-derivable.

/// xoshiro256** PRNG (public-domain reference algorithm by Blackman/Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/consecutive seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (for per-request / per-node rngs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0), Lemire-style rejection-free bound.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value; the pair is not cached to
    /// keep the generator state trivially forkable).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let lambda = 4.0;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = Rng::new(5);
        let mut b = a.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
