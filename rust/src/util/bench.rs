//! Minimal benchmarking harness (criterion substitute; criterion is not in
//! the offline crate cache). Provides warmup, repeated timed runs, and
//! summary statistics; used by the `benches/*.rs` targets
//! (`harness = false`).
//!
//! Benches accept a `--smoke` flag (`cargo bench --bench <name> -- --smoke`,
//! or `BENCH_SMOKE=1`): [`smoke`] reports it and [`smoke_scale`] shrinks
//! sweep sizes, so CI can *execute* every bench binary in seconds instead
//! of only compiling it (`make bench-smoke`).

use std::time::Instant;

/// True when the bench binary was invoked with `--smoke` (or with
/// `BENCH_SMOKE=1` in the environment): a quick-iteration run that keeps
/// the code paths but shrinks the workload.
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok_and(|v| v == "1")
}

/// `full` normally, `quick` under `--smoke`.
pub fn smoke_scale(full: usize, quick: usize) -> usize {
    if smoke() {
        quick
    } else {
        full
    }
}

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl BenchStats {
    pub fn summary(&self) -> String {
        format!(
            "{:<32} iters={:<6} mean={:>10} p50={:>10} p95={:>10}",
            self.name,
            self.iters,
            fmt_time(self.mean),
            fmt_time(self.p50),
            fmt_time(self.p95),
        )
    }
}

/// Human-readable duration.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// Time `f` for at least `min_iters` iterations and `min_secs` seconds
/// (after `warmup` untimed iterations). Returns per-iteration statistics.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize, min_secs: f64, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < min_secs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000_000 {
            break;
        }
    }
    stats_from(name, &mut samples)
}

/// Build stats from raw per-iteration samples.
pub fn stats_from(name: &str, samples: &mut [f64]) -> BenchStats {
    assert!(!samples.is_empty());
    samples.sort_by(f64::total_cmp);
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let q = |p: f64| samples[((n as f64 - 1.0) * p) as usize];
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean,
        p50: q(0.50),
        p95: q(0.95),
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---- BENCH_*.json emission ---------------------------------------------
//
// serde is not in the offline crate cache, so the perf benches render
// their artifacts through this tiny value tree instead. Rendering is
// deterministic: object keys keep insertion order, floats use Rust's
// shortest-roundtrip `Display`, non-finite floats become `null` (JSON
// has no representation for them and a bench metric should never
// produce one anyway).

/// A JSON value for bench artifacts ([`emit_json`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: an object from key/value pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Render with two-space indentation and a trailing newline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(x) => {
                if x.is_finite() {
                    let s = format!("{x}");
                    out.push_str(&s);
                    // `Display` omits the decimal point for integral
                    // floats; keep them unambiguously floats for
                    // downstream parsers.
                    if !s.contains(['.', 'e', 'E']) {
                        out.push_str(".0");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    pad(out, indent + 1);
                    item.write(out, indent + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < fields.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn pad(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

/// Write a bench artifact (`BENCH_*.json`) to `path`.
pub fn emit_json(path: &std::path::Path, value: &Json) -> std::io::Result<()> {
    std::fs::write(path, value.render())
}

/// Extract the first numeric value following `"key":` in a JSON text —
/// enough of a parser for the perf bench's regression gate to read one
/// scalar out of a checked-in baseline without serde. Returns `None` if
/// the key is absent or its value does not parse as a number.
pub fn json_number_field(text: &str, key: &str) -> Option<f64> {
    let needle = format!("\"{key}\"");
    let at = text.find(&needle)? + needle.len();
    let rest = text[at..].trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'E')))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let mut acc = 0u64;
        let s = bench("noop", 2, 50, 0.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
        assert!(s.mean > 0.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("us"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }

    #[test]
    fn json_renders_scalars_and_nesting() {
        let v = Json::obj(vec![
            ("name", Json::Str("des".into())),
            ("events", Json::Int(10_000_000)),
            ("events_per_sec", Json::Num(2.5e6)),
            ("whole", Json::Num(3.0)),
            ("ok", Json::Bool(true)),
            ("bad", Json::Num(f64::NAN)),
            ("empty", Json::Arr(vec![])),
            ("runs", Json::Arr(vec![Json::Int(1), Json::Int(2)])),
        ]);
        let s = v.render();
        assert!(s.contains("\"name\": \"des\""), "{s}");
        assert!(s.contains("\"events\": 10000000"), "{s}");
        assert!(s.contains("\"whole\": 3.0"), "{s}");
        assert!(s.contains("\"bad\": null"), "{s}");
        assert!(s.contains("\"empty\": []"), "{s}");
        assert!(s.ends_with("}\n"), "{s}");
    }

    #[test]
    fn json_escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).render();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"\n");
    }

    #[test]
    fn json_number_field_reads_back_emitted_values() {
        let v = Json::obj(vec![
            ("total_events_per_sec", Json::Num(1234567.89)),
            ("wall_secs", Json::Num(12.5)),
            ("neg", Json::Num(-3.5)),
        ]);
        let s = v.render();
        let x = json_number_field(&s, "total_events_per_sec").unwrap();
        assert!((x - 1234567.89).abs() < 1e-6, "{x}");
        assert_eq!(json_number_field(&s, "wall_secs"), Some(12.5));
        assert_eq!(json_number_field(&s, "neg"), Some(-3.5));
        assert_eq!(json_number_field(&s, "absent"), None);
    }

    #[test]
    fn emit_json_round_trips_through_a_file() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("harmonia_bench_json_{}.json", std::process::id()));
        let v = Json::obj(vec![("x", Json::Num(2.0))]);
        emit_json(&path, &v).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(json_number_field(&text, "x"), Some(2.0));
        let _ = std::fs::remove_file(&path);
    }
}
