//! Minimal benchmarking harness (criterion substitute; criterion is not in
//! the offline crate cache). Provides warmup, repeated timed runs, and
//! summary statistics; used by the `benches/*.rs` targets
//! (`harness = false`).
//!
//! Benches accept a `--smoke` flag (`cargo bench --bench <name> -- --smoke`,
//! or `BENCH_SMOKE=1`): [`smoke`] reports it and [`smoke_scale`] shrinks
//! sweep sizes, so CI can *execute* every bench binary in seconds instead
//! of only compiling it (`make bench-smoke`).

use std::time::Instant;

/// True when the bench binary was invoked with `--smoke` (or with
/// `BENCH_SMOKE=1` in the environment): a quick-iteration run that keeps
/// the code paths but shrinks the workload.
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok_and(|v| v == "1")
}

/// `full` normally, `quick` under `--smoke`.
pub fn smoke_scale(full: usize, quick: usize) -> usize {
    if smoke() {
        quick
    } else {
        full
    }
}

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl BenchStats {
    pub fn summary(&self) -> String {
        format!(
            "{:<32} iters={:<6} mean={:>10} p50={:>10} p95={:>10}",
            self.name,
            self.iters,
            fmt_time(self.mean),
            fmt_time(self.p50),
            fmt_time(self.p95),
        )
    }
}

/// Human-readable duration.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// Time `f` for at least `min_iters` iterations and `min_secs` seconds
/// (after `warmup` untimed iterations). Returns per-iteration statistics.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize, min_secs: f64, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < min_secs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000_000 {
            break;
        }
    }
    stats_from(name, &mut samples)
}

/// Build stats from raw per-iteration samples.
pub fn stats_from(name: &str, samples: &mut [f64]) -> BenchStats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let q = |p: f64| samples[((n as f64 - 1.0) * p) as usize];
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean,
        p50: q(0.50),
        p95: q(0.95),
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let mut acc = 0u64;
        let s = bench("noop", 2, 50, 0.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
        assert!(s.mean > 0.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("us"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }
}
