//! Clock abstraction so the same policy code (router, scheduler,
//! autoscaler) runs in live serving (wall clock) and in the discrete-event
//! simulator (virtual clock). Times are f64 seconds since an arbitrary
//! epoch.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A monotonically non-decreasing time source in seconds.
pub trait Clock: Send + Sync {
    fn now(&self) -> f64;
}

/// Wall clock anchored at construction.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Virtual clock driven by the DES loop. Stored as integer nanoseconds in
/// an atomic so policy code can read it from any thread without locks.
#[derive(Clone)]
pub struct VirtualClock {
    nanos: Arc<AtomicU64>,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { nanos: Arc::new(AtomicU64::new(0)) }
    }

    /// Advance to an absolute time; DES event loops must only move forward.
    pub fn advance_to(&self, t: f64) {
        let n = (t * 1e9) as u64;
        self.nanos.fetch_max(n, Ordering::Relaxed);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_monotone() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_advances_and_never_goes_back() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(1.0); // ignored
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(2.25);
        assert!((c.now() - 2.25).abs() < 1e-9);
    }
}
