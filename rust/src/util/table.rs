//! Plain-text table printer used by the bench harnesses to print the same
//! rows/series the paper's tables and figures report.

/// A simple left-aligned column table with a title.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&v)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals — convenience for table rows.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer  2.5"));
        // header padded to column width
        assert!(s.contains("name    value"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
