//! Small shared utilities: PRNG, clocks, table printing, a criterion
//! substitute ([`bench`]) and a proptest substitute ([`proptest`]) — the
//! offline crate cache only contains `xla` + `anyhow`, so these are built
//! in-crate.

pub mod bench;
pub mod clock;
pub mod proptest;
pub mod rng;
pub mod table;
