"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. ``python/tests/`` asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-driven shape and
value sweeps — this is the core correctness signal for Layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def ref_decode_attention(q, k, v, pos):
    """Single-token (decode-step) attention against a KV cache.

    Args:
      q:   [B, H, Dh]  query for the new token.
      k:   [B, H, S, Dh] key cache (positions > pos[b] are garbage).
      v:   [B, H, S, Dh] value cache.
      pos: [B] int32, index of the new token; positions 0..pos inclusive
           are attended (the new token's k/v is already written at pos).

    Returns: [B, H, Dh] attention output (f32).
    """
    B, H, S, Dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    s = jnp.einsum(
        "bhd,bhsd->bhs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    idx = jnp.arange(S)[None, None, :]
    mask = idx <= pos[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", p, v.astype(jnp.float32))


def ref_prefill_attention(q, k, v, length):
    """Causal self-attention over a (padded) prompt.

    Args:
      q, k, v: [B, H, S, Dh].
      length:  [B] int32 valid prompt length; keys at >= length are masked.

    Returns: [B, H, S, Dh] (f32). Rows at query positions >= length attend
    only to valid keys and are numerically well-defined but unused
    downstream.
    """
    B, H, S, Dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qi = jnp.arange(S)[None, None, :, None]
    ki = jnp.arange(S)[None, None, None, :]
    causal = ki <= qi
    valid = ki < length[:, None, None, None]
    # Every query row always sees key 0 or itself, so the softmax is never
    # fully masked for rows < length; rows >= length still see key <= qi.
    s = jnp.where(causal & valid, s, NEG_INF)
    # Guard fully-masked rows (q rows beyond length when length == 0).
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def ref_score(q, docs):
    """Dense retrieval scoring: dot-product similarity.

    Args:
      q:    [B, D] query embeddings.
      docs: [N, D] corpus-shard embeddings.

    Returns: [B, N] scores (f32).
    """
    return q.astype(jnp.float32) @ docs.astype(jnp.float32).T
