"""Pallas retrieval-scoring kernel (Layer 1 — the retrieval hot-spot).

Dense dot-product scoring of a query batch against a corpus shard,
``scores = q @ docsᵀ``, tiled over the corpus dimension so each grid step
streams one VMEM-sized block of document embeddings from HBM. This is the
TPU rethink of ChromaDB's CPU scoring loop: the candidate scan that
``search_ef`` bounds becomes a sequence of MXU matmul tiles; the Rust-side
IVF store (rust/src/retrieval) chooses *which* shards/blocks to scan, the
kernel makes each scanned block MXU-shaped.

Top-k selection itself is done by the caller (``jax.lax.top_k`` at Layer 2
or the Rust heap-select at Layer 3) — selection is memory-light and control
heavy, exactly what should NOT live in the systolic array.

VMEM accounting (B=8, D=64, BLK_N=256, f32): q tile 8·64·4 = 2 KiB,
doc tile 256·64·4 = 64 KiB, out tile 8·256·4 = 8 KiB per grid step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Documents per grid step. 256 rows × D columns keeps each streamed tile
# 128-aligned on the corpus axis (MXU-friendly) and well under VMEM.
BLK_N = 256


def _score_kernel(q_ref, d_ref, o_ref):
    """Grid point = one corpus tile.

    Refs: q_ref [B, D] (whole query batch, resident across steps),
          d_ref [BLK_N, D] (this step's corpus tile),
          o_ref [B, BLK_N].
    """
    q = q_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    o_ref[...] = q @ d.T


def score(q, docs):
    """Blocked similarity scoring: q [B, D] × docs [N, D] → [B, N] f32.

    N must be a multiple of BLK_N (the Rust store pads shards).
    """
    B, D = q.shape
    N, D2 = docs.shape
    assert D == D2, f"dim mismatch {D} vs {D2}"
    assert N % BLK_N == 0, f"N={N} must be a multiple of {BLK_N}"
    return pl.pallas_call(
        _score_kernel,
        grid=(N // BLK_N,),
        in_specs=[
            pl.BlockSpec((B, D), lambda i: (0, 0)),
            pl.BlockSpec((BLK_N, D), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((B, BLK_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=True,
    )(q, docs)
