"""Pallas flash-attention kernels (Layer 1 — the generation hot-spot).

Two kernels, both written as blocked online-softmax loops over KV tiles:

* :func:`decode_attention` — one new query token per sequence against a
  padded KV cache (the per-step cost of autoregressive decoding). This is
  the TPU rethink of vLLM's PagedAttention: where PagedAttention walks KV
  *pages* with a CUDA threadblock per (head, sequence), we tile the KV
  cache into VMEM-sized blocks with ``BlockSpec`` and accumulate an online
  softmax across the tiles; the grid dimension (b, h) takes the role of the
  threadblock index, and the HBM→VMEM block schedule takes the role of the
  page-table walk.

* :func:`prefill_attention` — causal attention over the whole prompt,
  tiled over query blocks (grid) × key blocks (inner ``fori_loop``), the
  classic FlashAttention schedule.

Both MUST be lowered with ``interpret=True`` in this environment: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Numerics are validated against :mod:`ref` by ``python/tests``.

VMEM accounting (for DESIGN.md §Perf; S=128, Dh=16, f32):
  decode:  per (b,h) grid step holds q [Dh] + one KV tile [BLK_S, Dh] × 2
           + accumulators → ≈ 2·64·16·4 B ≈ 8 KiB, far under the ~16 MiB
           VMEM budget; the grid is compute-bound on the MXU row-matmul.
  prefill: q tile [BLK_Q, Dh] + KV tiles [BLK_K, Dh] × 2 + p [BLK_Q, BLK_K]
           ≈ 64·64·4 B · 4 ≈ 64 KiB per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# KV-tile length for the decode kernel. 64 keeps the working set tiny while
# exercising the multi-tile online-softmax path for S >= 128.
BLK_S = 64
# Query/key tile lengths for the prefill kernel.
BLK_Q = 64
BLK_K = 64


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, s_total: int):
    """Grid point = (batch b, head h). Online softmax over KV tiles.

    Refs (after BlockSpec squeezing):
      pos_ref: [1]       int32  position of the new token for this b.
      q_ref:   [Dh]      query row.
      k_ref:   [S, Dh]   full key-cache row for (b, h); tiled inside.
      v_ref:   [S, Dh]   full value-cache row.
      o_ref:   [Dh]      output.
    """
    dh = q_ref.shape[-1]
    pos = pos_ref[0]
    q = q_ref[...].astype(jnp.float32) * (1.0 / jnp.sqrt(jnp.float32(dh)))

    n_blk = s_total // BLK_S

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[...], (i * BLK_S, 0), (BLK_S, dh)
        ).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[...], (i * BLK_S, 0), (BLK_S, dh)
        ).astype(jnp.float32)
        s = k_blk @ q  # [BLK_S]
        idx = i * BLK_S + jax.lax.iota(jnp.int32, BLK_S)
        s = jnp.where(idx <= pos, s, NEG_INF)
        m_cur = jnp.max(s)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # [BLK_S]
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p)
        acc = acc * alpha + p @ v_blk  # [Dh]
        return m_new, l_new, acc

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((dh,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_blk, body, (m0, l0, acc0))
    o_ref[...] = acc / l


def decode_attention(q, k, v, pos):
    """Pallas decode-step attention. Shapes as :func:`ref.ref_decode_attention`.

    q: [B, H, Dh]; k, v: [B, H, S, Dh]; pos: [B] int32 → out [B, H, Dh] f32.
    S must be a multiple of BLK_S.
    """
    B, H, S, Dh = k.shape
    assert S % BLK_S == 0, f"S={S} must be a multiple of {BLK_S}"
    kern = functools.partial(_decode_kernel, s_total=S)
    return pl.pallas_call(
        kern,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h: (b,)),  # pos
            pl.BlockSpec((None, None, Dh), lambda b, h: (b, h, 0)),  # q
            pl.BlockSpec((None, None, S, Dh), lambda b, h: (b, h, 0, 0)),  # k
            pl.BlockSpec((None, None, S, Dh), lambda b, h: (b, h, 0, 0)),  # v
        ],
        out_specs=pl.BlockSpec((None, None, Dh), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
        interpret=True,
    )(pos, q, k, v)


def _prefill_kernel(len_ref, q_ref, k_ref, v_ref, o_ref):
    """Grid point = (b, h, q-tile). Flash loop over k tiles ≤ q tile end.

    Refs (after BlockSpec squeezing):
      len_ref: [1]            int32 valid length for this b.
      q_ref:   [BLK_Q, Dh]
      k_ref:   [S, Dh]
      v_ref:   [S, Dh]
      o_ref:   [BLK_Q, Dh]
    """
    dh = q_ref.shape[-1]
    qi_blk = pl.program_id(2)
    length = len_ref[0]
    q = q_ref[...].astype(jnp.float32) * (1.0 / jnp.sqrt(jnp.float32(dh)))
    q_idx = qi_blk * BLK_Q + jax.lax.iota(jnp.int32, BLK_Q)  # [BLK_Q]

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[...], (i * BLK_K, 0), (BLK_K, dh)
        ).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_ref[...], (i * BLK_K, 0), (BLK_K, dh)
        ).astype(jnp.float32)
        s = q @ k_blk.T  # [BLK_Q, BLK_K]
        k_idx = i * BLK_K + jax.lax.iota(jnp.int32, BLK_K)  # [BLK_K]
        mask = (k_idx[None, :] <= q_idx[:, None]) & (k_idx[None, :] < length)
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)  # [BLK_Q]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_blk
        return m_new, l_new, acc

    m0 = jnp.full((BLK_Q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((BLK_Q,), jnp.float32)
    acc0 = jnp.zeros((BLK_Q, dh), jnp.float32)
    # Causality: k tiles strictly after this q tile contribute nothing, so
    # the loop runs only to qi_blk + 1 — the flash-attention work saving.
    _, l, acc = jax.lax.fori_loop(0, qi_blk + 1, body, (m0, l0, acc0))
    # Rows with q_idx >= length are padding; their softmax may be fully
    # masked (all NEG_INF). exp(NEG_INF - NEG_INF) = 1 keeps l >= 1 in that
    # case, so the division is safe; guard against pathological zeros.
    l = jnp.maximum(l, 1e-30)
    o_ref[...] = acc / l[:, None]


def prefill_attention(q, k, v, length):
    """Pallas causal prefill attention.

    q, k, v: [B, H, S, Dh]; length: [B] int32 → out [B, H, S, Dh] f32.
    S must be a multiple of BLK_Q (= BLK_K).
    """
    B, H, S, Dh = q.shape
    assert S % BLK_Q == 0 and BLK_Q == BLK_K
    n_q = S // BLK_Q
    return pl.pallas_call(
        _prefill_kernel,
        grid=(B, H, n_q),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i: (b,)),  # length
            pl.BlockSpec((None, None, BLK_Q, Dh), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, S, Dh), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, Dh), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, None, BLK_Q, Dh), lambda b, h, i: (b, h, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, S, Dh), jnp.float32),
        interpret=True,
    )(length, q, k, v)
