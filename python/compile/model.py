"""Layer 2 — JAX compute graphs for every model the serving stack needs.

All graphs call the Layer-1 Pallas kernels (``kernels/``) for their
hot-spots and are lowered ONCE by :mod:`compile.aot` to HLO-text artifacts
executed from Rust via PJRT. Python never runs on the request path.

Models (weights are generated deterministically from fixed seeds and baked
into the HLO as constants — the artifacts are self-contained):

* **Generator LM** — a byte-level GPT (V=256, D=64, 2 layers, 4 heads,
  S=128) with ``prefill`` / ``decode_step`` entry points and an explicit KV
  cache threaded through the artifact boundary. Serves as the paper's
  generator, grader, critic and rewriter (same weights, different prompts —
  matching how the paper reuses "an LLM" for auxiliary roles).
* **Embedder** — token embedding + masked mean-pool + 2-layer MLP,
  L2-normalized output. Used to embed both corpus passages (index build)
  and queries.
* **Classifier** — 3-way MLP over query embeddings: the Adaptive-RAG
  query-complexity classifier (classes: simple / standard / complex).
* **Retrieval scorer** — the Pallas blocked-matmul scoring kernel wrapped
  for a fixed shard shape.

Shapes are fixed per artifact (PJRT AOT requires static shapes); the Rust
runtime pads batches and shards to these shapes (see ``artifacts/manifest``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import flash_attention as fa
from compile.kernels import topk_score as ts

# ----------------------------------------------------------------------------
# Configuration (mirrored in artifacts/manifest.txt → rust/src/runtime).
# ----------------------------------------------------------------------------

CONFIG = dict(
    vocab=256,        # byte-level tokens
    d_model=64,
    n_layers=2,
    n_heads=4,
    d_head=16,
    d_ffn=256,
    max_seq=128,      # generator context length
    embed_seq=64,     # embedder input length
    embed_dim=64,     # embedding dimensionality (== d_model)
    n_classes=3,      # A-RAG complexity classes
    shard_n=1024,     # corpus shard rows per retrieval_score call
)

PARAM_SEED = 0


def _norm(rng, shape, scale):
    return jax.random.normal(rng, shape, jnp.float32) * scale


def init_lm_params(seed: int = PARAM_SEED):
    """Deterministic tiny-GPT parameters."""
    c = CONFIG
    d, h, dh, f, v, s = (
        c["d_model"], c["n_heads"], c["d_head"], c["d_ffn"], c["vocab"],
        c["max_seq"],
    )
    rngs = jax.random.split(jax.random.PRNGKey(seed), 4 + 8 * c["n_layers"])
    it = iter(rngs)
    p = {
        "tok_emb": _norm(next(it), (v, d), 0.02),
        "pos_emb": _norm(next(it), (s, d), 0.02),
        "ln_f_g": jnp.ones((d,)),
        "out": _norm(next(it), (d, v), d ** -0.5),
    }
    next(it)
    for l in range(c["n_layers"]):
        p[f"l{l}"] = {
            "ln1_g": jnp.ones((d,)),
            "ln2_g": jnp.ones((d,)),
            "wq": _norm(next(it), (d, h * dh), d ** -0.5),
            "wk": _norm(next(it), (d, h * dh), d ** -0.5),
            "wv": _norm(next(it), (d, h * dh), d ** -0.5),
            "wo": _norm(next(it), (h * dh, d), (h * dh) ** -0.5),
            "w1": _norm(next(it), (d, f), d ** -0.5),
            "b1": jnp.zeros((f,)),
            "w2": _norm(next(it), (f, d), f ** -0.5),
            "b2": jnp.zeros((d,)),
        }
    return p


def init_embedder_params(seed: int = PARAM_SEED + 1):
    c = CONFIG
    d, e = c["d_model"], c["embed_dim"]
    r = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {
        "tok_emb": _norm(r[0], (c["vocab"], d), 0.05),
        "w1": _norm(r[1], (d, 2 * d), d ** -0.5),
        "b1": jnp.zeros((2 * d,)),
        "w2": _norm(r[2], (2 * d, e), (2 * d) ** -0.5),
        "b2": jnp.zeros((e,)),
    }


def init_classifier_params(seed: int = PARAM_SEED + 2):
    c = CONFIG
    e, n = c["embed_dim"], c["n_classes"]
    r = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {
        "w1": _norm(r[0], (e, 32), e ** -0.5),
        "b1": jnp.zeros((32,)),
        "w2": _norm(r[1], (32, n), 32 ** -0.5),
        "b2": jnp.zeros((n,)),
    }


# ----------------------------------------------------------------------------
# Transformer blocks.
# ----------------------------------------------------------------------------


def _layernorm(x, g):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g


def _split_heads(x, B, S, H, Dh):
    # [B, S, H*Dh] -> [B, H, S, Dh]
    return x.reshape(B, S, H, Dh).transpose(0, 2, 1, 3)


def lm_prefill(params, tokens, length):
    """Prompt prefill.

    Args:
      tokens: [B, S] int32 (padded with 0 beyond length).
      length: [B] int32 valid lengths (>= 1).

    Returns:
      logits: [B, V] next-token logits at position length-1.
      kv:     [L, 2, B, H, S, Dh] KV cache (positions >= length are pad
              contributions, masked by downstream decode).
    """
    c = CONFIG
    B, S = tokens.shape
    H, Dh = c["n_heads"], c["d_head"]
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :S, :]
    kv_layers = []
    for l in range(c["n_layers"]):
        lp = params[f"l{l}"]
        h_in = _layernorm(x, lp["ln1_g"])
        q = _split_heads(h_in @ lp["wq"], B, S, H, Dh)
        k = _split_heads(h_in @ lp["wk"], B, S, H, Dh)
        v = _split_heads(h_in @ lp["wv"], B, S, H, Dh)
        attn = fa.prefill_attention(q, k, v, length)  # [B,H,S,Dh] f32
        attn = attn.transpose(0, 2, 1, 3).reshape(B, S, H * Dh)
        x = x + attn @ lp["wo"]
        h2 = _layernorm(x, lp["ln2_g"])
        x = x + jax.nn.gelu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]
        kv_layers.append(jnp.stack([k, v]))  # [2,B,H,S,Dh]
    kv = jnp.stack(kv_layers)  # [L,2,B,H,S,Dh]
    x = _layernorm(x, params["ln_f_g"])
    # Gather the hidden state at the last valid position per sequence.
    last = jnp.take_along_axis(
        x, (length - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]  # [B, D]
    logits = last @ params["out"]
    return logits, kv


def lm_decode_step(params, kv, token, pos):
    """One autoregressive decode step.

    Args:
      kv:    [L, 2, B, H, S, Dh] cache from prefill / previous steps.
      token: [B] int32 token sampled at the previous step.
      pos:   [B] int32 position at which `token` sits (== current length-1
             before this call writes k/v for `token` at pos).

    Returns:
      logits: [B, V] next-token logits.
      kv_new: updated cache with this token's k/v written at pos.
    """
    c = CONFIG
    L, _, B, H, S, Dh = kv.shape
    x = params["tok_emb"][token] + params["pos_emb"][pos]  # [B, D]
    kv_out = []
    for l in range(c["n_layers"]):
        lp = params[f"l{l}"]
        h_in = _layernorm(x, lp["ln1_g"])
        q = (h_in @ lp["wq"]).reshape(B, H, Dh)
        k_new = (h_in @ lp["wk"]).reshape(B, H, Dh)
        v_new = (h_in @ lp["wv"]).reshape(B, H, Dh)

        def write(cache, new):
            # cache [B,H,S,Dh], new [B,H,Dh]: write row at pos[b] per batch.
            def one(cb, nb, pb):
                return jax.lax.dynamic_update_slice(
                    cb, nb[:, None, :], (0, pb, 0)
                )
            return jax.vmap(one)(cache, new, pos)

        k_cache = write(kv[l, 0], k_new)
        v_cache = write(kv[l, 1], v_new)
        attn = fa.decode_attention(q, k_cache, v_cache, pos)  # [B,H,Dh]
        x = x + attn.reshape(B, H * Dh) @ lp["wo"]
        h2 = _layernorm(x, lp["ln2_g"])
        x = x + jax.nn.gelu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]
        kv_out.append(jnp.stack([k_cache, v_cache]))
    kv_new = jnp.stack(kv_out)
    x = _layernorm(x, params["ln_f_g"])
    logits = x @ params["out"]
    return logits, kv_new


# ----------------------------------------------------------------------------
# Embedder / classifier / retrieval scorer.
# ----------------------------------------------------------------------------


def embed(params, tokens, length):
    """tokens [B, S_E] int32, length [B] int32 → L2-normalized [B, E] f32."""
    B, S = tokens.shape
    x = params["tok_emb"][tokens]  # [B, S, D]
    mask = (jnp.arange(S)[None, :] < length[:, None]).astype(jnp.float32)
    pooled = jnp.sum(x * mask[:, :, None], axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1, keepdims=True), 1.0
    )
    h = jax.nn.gelu(pooled @ params["w1"] + params["b1"])
    e = h @ params["w2"] + params["b2"]
    return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-6)


def classify(params, emb):
    """emb [B, E] → class logits [B, n_classes]."""
    h = jax.nn.gelu(emb @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def retrieval_score(q, docs):
    """q [B, E] × docs [N, E] → scores [B, N] via the Pallas kernel."""
    return ts.score(q, docs)


# ----------------------------------------------------------------------------
# Reference (pure-jnp) model paths for L2 testing: identical math with
# ref-kernel attention, used by python/tests/test_model.py.
# ----------------------------------------------------------------------------


def lm_prefill_ref(params, tokens, length):
    from compile.kernels import ref as R

    c = CONFIG
    B, S = tokens.shape
    H, Dh = c["n_heads"], c["d_head"]
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :S, :]
    for l in range(c["n_layers"]):
        lp = params[f"l{l}"]
        h_in = _layernorm(x, lp["ln1_g"])
        q = _split_heads(h_in @ lp["wq"], B, S, H, Dh)
        k = _split_heads(h_in @ lp["wk"], B, S, H, Dh)
        v = _split_heads(h_in @ lp["wv"], B, S, H, Dh)
        attn = R.ref_prefill_attention(q, k, v, length)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, S, H * Dh)
        x = x + attn @ lp["wo"]
        h2 = _layernorm(x, lp["ln2_g"])
        x = x + jax.nn.gelu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]
    x = _layernorm(x, params["ln_f_g"])
    last = jnp.take_along_axis(
        x, (length - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]
    return last @ params["out"]
