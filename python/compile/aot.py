"""AOT lowering: JAX entry points → HLO-text artifacts + manifest.

Interchange format is **HLO text**, NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Run via ``make artifacts`` (``python -m compile.aot --out ../artifacts``).
Emits one ``<name>.hlo.txt`` per entry point plus ``manifest.txt``, a
line-based description the Rust runtime parses (rust/src/runtime/manifest.rs):

    config vocab 256
    ...
    artifact generator_decode_b8
    path generator_decode_b8.hlo.txt
    input kv f32 2,2,8,4,128,16
    input token i32 8
    input pos i32 8
    output logits f32 8,256
    output kv f32 2,2,8,4,128,16
    end

Weights are baked into the HLO as constants; artifacts are self-contained.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Batch sizes compiled for the generator. The Rust batcher pads the running
# batch up to the nearest compiled size (vLLM-style bucketed batching).
GEN_BATCH_SIZES = (1, 2, 4, 8)
EMB_BATCH = 8
CLS_BATCH = 8
SCORE_BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: weights are baked into the module; the
    # default printer elides big literals as `{...}`, which would not
    # round-trip through the Rust-side text parser.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_name(d):
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(d).name]


class Manifest:
    def __init__(self):
        self.lines = []
        c = model.CONFIG
        for k, v in c.items():
            self.lines.append(f"config {k} {v}")
        self.lines.append(f"config gen_batch_sizes {','.join(map(str, GEN_BATCH_SIZES))}")

    def add(self, name, path, inputs, outputs):
        self.lines.append(f"artifact {name}")
        self.lines.append(f"path {path}")
        for nm, s in inputs:
            self.lines.append(
                f"input {nm} {_dtype_name(s.dtype)} {','.join(map(str, s.shape))}"
            )
        for nm, s in outputs:
            self.lines.append(
                f"output {nm} {_dtype_name(s.dtype)} {','.join(map(str, s.shape))}"
            )
        self.lines.append("end")

    def write(self, path):
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")


def lower_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    c = model.CONFIG
    L, H, S, Dh = c["n_layers"], c["n_heads"], c["max_seq"], c["d_head"]
    V, E, SE, NC, SN = (
        c["vocab"], c["embed_dim"], c["embed_seq"], c["n_classes"], c["shard_n"],
    )
    lm = model.init_lm_params()
    emb_p = model.init_embedder_params()
    cls_p = model.init_classifier_params()
    man = Manifest()

    def emit(name, fn, inputs):
        specs = [s for _, s in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *specs)
        # fns return tuples; name outputs positionally.
        outs = []
        flat, _ = jax.tree_util.tree_flatten(out_tree)
        names = _output_names(name, len(flat))
        for nm, s in zip(names, flat):
            outs.append((nm, s))
        man.add(name, fname, inputs, outs)
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO text")

    def _output_names(name, n):
        if name.startswith("generator_prefill") or name.startswith("generator_decode"):
            return ["logits", "kv"][:n]
        if name.startswith("embedder"):
            return ["emb"]
        if name.startswith("classifier"):
            return ["logits"]
        if name.startswith("retrieval_score"):
            return ["scores"]
        return [f"out{i}" for i in range(n)]

    for B in GEN_BATCH_SIZES:
        emit(
            f"generator_prefill_b{B}",
            functools.partial(lambda t, ln: model.lm_prefill(lm, t, ln)),
            [("tokens", _spec((B, S), jnp.int32)), ("length", _spec((B,), jnp.int32))],
        )
        emit(
            f"generator_decode_b{B}",
            lambda kv, t, p: model.lm_decode_step(lm, kv, t, p),
            [
                ("kv", _spec((L, 2, B, H, S, Dh), jnp.float32)),
                ("token", _spec((B,), jnp.int32)),
                ("pos", _spec((B,), jnp.int32)),
            ],
        )
    emit(
        "embedder",
        lambda t, ln: (model.embed(emb_p, t, ln),),
        [("tokens", _spec((EMB_BATCH, SE), jnp.int32)), ("length", _spec((EMB_BATCH,), jnp.int32))],
    )
    emit(
        "classifier",
        lambda e: (model.classify(cls_p, e),),
        [("emb", _spec((CLS_BATCH, E), jnp.float32))],
    )
    emit(
        "retrieval_score",
        lambda q, d: (model.retrieval_score(q, d),),
        [("q", _spec((SCORE_BATCH, E), jnp.float32)), ("docs", _spec((SN, E), jnp.float32))],
    )
    man.write(os.path.join(out_dir, "manifest.txt"))
    print(f"wrote manifest with {len(man.lines)} lines to {out_dir}/manifest.txt")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    lower_all(args.out)


if __name__ == "__main__":
    main()
