"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes, and mask positions; every property
asserts allclose against ``kernels/ref.py``. This is the core correctness
signal for the compute hot-spots that end up inside the AOT artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from compile.kernels import flash_attention as fa
from compile.kernels import topk_score as ts
from compile.kernels import ref

RTOL = 2e-5
ATOL = 2e-5


def _rand(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(dtype) * scale)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    s_blocks=st.integers(1, 3),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, s_blocks, dh, seed):
    s = s_blocks * fa.BLK_S
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, dh))
    k = _rand(rng, (b, h, s, dh))
    v = _rand(rng, (b, h, s, dh))
    pos = jnp.asarray(rng.integers(0, s, size=(b,)), jnp.int32)
    out = fa.decode_attention(q, k, v, pos)
    exp = ref.ref_decode_attention(q, k, v, pos)
    np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)


def test_decode_attention_pos_zero_attends_only_first_key():
    rng = np.random.default_rng(0)
    b, h, s, dh = 2, 2, fa.BLK_S * 2, 16
    q = _rand(rng, (b, h, dh))
    k = _rand(rng, (b, h, s, dh))
    v = _rand(rng, (b, h, s, dh))
    pos = jnp.zeros((b,), jnp.int32)
    out = fa.decode_attention(q, k, v, pos)
    # With only one unmasked key the output must equal v[:, :, 0, :].
    np.testing.assert_allclose(out, v[:, :, 0, :], rtol=RTOL, atol=ATOL)


def test_decode_attention_ignores_garbage_beyond_pos():
    rng = np.random.default_rng(1)
    b, h, s, dh = 1, 2, fa.BLK_S * 2, 16
    q = _rand(rng, (b, h, dh))
    k = _rand(rng, (b, h, s, dh))
    v = _rand(rng, (b, h, s, dh))
    pos = jnp.asarray([17], jnp.int32)
    out1 = fa.decode_attention(q, k, v, pos)
    # Poison everything beyond pos: result must not change.
    k2 = k.at[:, :, 18:, :].set(1e9)
    v2 = v.at[:, :, 18:, :].set(-1e9)
    out2 = fa.decode_attention(q, k2, v2, pos)
    np.testing.assert_allclose(out1, out2, rtol=RTOL, atol=ATOL)


def test_decode_attention_large_scores_numerically_stable():
    rng = np.random.default_rng(2)
    b, h, s, dh = 2, 1, fa.BLK_S, 8
    q = _rand(rng, (b, h, dh), scale=50.0)
    k = _rand(rng, (b, h, s, dh), scale=50.0)
    v = _rand(rng, (b, h, s, dh))
    pos = jnp.asarray([s - 1] * b, jnp.int32)
    out = fa.decode_attention(q, k, v, pos)
    assert np.isfinite(np.asarray(out)).all()
    exp = ref.ref_decode_attention(q, k, v, pos)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# prefill_attention
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    s_blocks=st.integers(1, 2),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_attention_matches_ref(b, h, s_blocks, dh, seed):
    s = s_blocks * fa.BLK_Q
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, s, dh))
    k = _rand(rng, (b, h, s, dh))
    v = _rand(rng, (b, h, s, dh))
    length = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    out = fa.prefill_attention(q, k, v, length)
    exp = ref.ref_prefill_attention(q, k, v, length)
    # Only compare rows < length; padding rows are unused downstream.
    out_np, exp_np = np.asarray(out), np.asarray(exp)
    for i, ln in enumerate(np.asarray(length)):
        np.testing.assert_allclose(
            out_np[i, :, :ln], exp_np[i, :, :ln], rtol=RTOL, atol=ATOL
        )


def test_prefill_first_row_is_v0():
    rng = np.random.default_rng(3)
    b, h, s, dh = 2, 2, fa.BLK_Q, 16
    q = _rand(rng, (b, h, s, dh))
    k = _rand(rng, (b, h, s, dh))
    v = _rand(rng, (b, h, s, dh))
    length = jnp.asarray([s] * b, jnp.int32)
    out = fa.prefill_attention(q, k, v, length)
    # Query row 0 can only attend to key 0.
    np.testing.assert_allclose(out[:, :, 0, :], v[:, :, 0, :], rtol=RTOL, atol=ATOL)


def test_prefill_causality():
    """Changing k/v at position j must not affect outputs at rows < j."""
    rng = np.random.default_rng(4)
    b, h, s, dh = 1, 2, fa.BLK_Q * 2, 8
    q = _rand(rng, (b, h, s, dh))
    k = _rand(rng, (b, h, s, dh))
    v = _rand(rng, (b, h, s, dh))
    length = jnp.asarray([s], jnp.int32)
    out1 = fa.prefill_attention(q, k, v, length)
    j = 70
    k2 = k.at[:, :, j:, :].add(3.0)
    v2 = v.at[:, :, j:, :].add(-2.0)
    out2 = fa.prefill_attention(q, k2, v2, length)
    np.testing.assert_allclose(out1[:, :, :j], out2[:, :, :j], rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# topk_score
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([1, 4, 8]),
    d=st.sampled_from([16, 64]),
    n_blocks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_score_matches_ref(b, d, n_blocks, seed):
    n = n_blocks * ts.BLK_N
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, d))
    docs = _rand(rng, (n, d))
    out = ts.score(q, docs)
    exp = ref.ref_score(q, docs)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


def test_score_identity_rows():
    """A query equal to a corpus row scores highest on that row (unit vectors)."""
    d, n = 64, 2 * ts.BLK_N
    rng = np.random.default_rng(5)
    docs = rng.normal(size=(n, d)).astype(np.float32)
    docs /= np.linalg.norm(docs, axis=1, keepdims=True)
    rows = [3, 77, 200, n - 1]
    q = jnp.asarray(docs[rows])
    out = np.asarray(ts.score(q, jnp.asarray(docs)))
    assert list(out.argmax(axis=1)) == rows


def test_score_rejects_bad_shard():
    with pytest.raises(AssertionError):
        ts.score(jnp.zeros((2, 8)), jnp.zeros((ts.BLK_N + 1, 8)))
