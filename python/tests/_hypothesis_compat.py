"""Hypothesis gate: re-export the real library when it is installed,
otherwise fall back to a tiny deterministic re-implementation of the
subset these tests use (``given``/``settings``/``integers``/
``sampled_from``).

The offline test image does not ship ``hypothesis``; without this shim
the whole module fails at collection and the non-property tests are lost
with it. The fallback runs each property against a fixed number of
seeded samples, so the suite stays meaningful (if less adversarial)
everywhere.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    import random

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rnd):
            return self._draw(rnd)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def sampled_from(values):
            values = list(values)
            return _Strategy(lambda rnd: values[rnd.randrange(len(values))])

    st = _Strategies()

    def settings(**_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # NOTE: no functools.wraps — copying fn's signature would make
            # pytest treat the property arguments as fixtures.
            def wrapper():
                rnd = random.Random(0xC0FFEE)
                for _ in range(8):
                    kwargs = {k: s.draw(rnd) for k, s in strategies.items()}
                    fn(**kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
