"""AOT artifact sanity: manifest structure and HLO-text integrity.

These tests run against a freshly lowered (small) artifact set in a temp
dir so they don't depend on `make artifacts` having run, plus quick
integrity checks on the real artifacts/ dir when it exists.
"""

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def arts(tmp_path_factory):
    """Lower the cheap entry points once into a temp dir."""
    out = tmp_path_factory.mktemp("arts")
    import jax
    import jax.numpy as jnp

    man = aot.Manifest()
    emb_p = model.init_embedder_params()
    lowered = jax.jit(lambda t, ln: (model.embed(emb_p, t, ln),)).lower(
        jax.ShapeDtypeStruct((8, model.CONFIG["embed_seq"]), jnp.int32),
        jax.ShapeDtypeStruct((8,), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    p = out / "embedder.hlo.txt"
    p.write_text(text)
    man.add(
        "embedder",
        "embedder.hlo.txt",
        [
            ("tokens", jax.ShapeDtypeStruct((8, 64), jnp.int32)),
            ("length", jax.ShapeDtypeStruct((8,), jnp.int32)),
        ],
        [("emb", jax.ShapeDtypeStruct((8, 64), jnp.float32))],
    )
    man.write(out / "manifest.txt")
    return out


def test_hlo_text_has_entry_and_no_elided_constants(arts):
    text = (arts / "embedder.hlo.txt").read_text()
    assert "ENTRY" in text
    assert "constant({...})" not in text, "large constants must be printed"


def test_manifest_roundtrip_structure(arts):
    lines = (arts / "manifest.txt").read_text().strip().splitlines()
    assert lines[0].startswith("config vocab")
    assert "artifact embedder" in lines
    i = lines.index("artifact embedder")
    block = lines[i : lines.index("end", i) + 1]
    kinds = [l.split()[0] for l in block]
    assert kinds == ["artifact", "path", "input", "input", "output", "end"]
    # shape encoding: comma-separated dims, dtype tag f32/i32
    tok = [l for l in block if l.startswith("input tokens")][0]
    assert tok == "input tokens i32 8,64"


def test_dtype_names():
    import jax.numpy as jnp

    assert aot._dtype_name(jnp.float32) == "f32"
    assert aot._dtype_name(jnp.int32) == "i32"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.txt")),
    reason="real artifacts not built",
)
def test_real_artifacts_integrity():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    man = open(os.path.join(root, "manifest.txt")).read()
    names = [l.split()[1] for l in man.splitlines() if l.startswith("artifact ")]
    # every generator batch size + the three auxiliaries
    for b in aot.GEN_BATCH_SIZES:
        assert f"generator_prefill_b{b}" in names
        assert f"generator_decode_b{b}" in names
    for aux in ("embedder", "classifier", "retrieval_score"):
        assert aux in names
    for l in man.splitlines():
        if l.startswith("path "):
            p = os.path.join(root, l.split()[1])
            assert os.path.exists(p), p
            head = open(p).read(200000)
            assert "ENTRY" in head
