"""Layer-2 correctness: model graphs built on the Pallas kernels.

Key invariants:
* prefill logits match the pure-ref transformer path (kernel vs ref attention);
* decode is consistent with prefill (teacher-forcing invariance): prefilling
  n+1 tokens gives the same logits as prefilling n then decoding one step;
* embedder output is unit-norm and padding-invariant;
* classifier shapes/determinism.
"""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from compile import model

LM = model.init_lm_params()
EMB = model.init_embedder_params()
CLS = model.init_classifier_params()
C = model.CONFIG


def _tokens(rng, b, s):
    return jnp.asarray(rng.integers(1, C["vocab"], size=(b, s)), jnp.int32)


def test_prefill_matches_ref_path():
    rng = np.random.default_rng(0)
    b, s = 2, C["max_seq"]
    toks = _tokens(rng, b, s)
    length = jnp.asarray([5, 100], jnp.int32)
    logits, kv = model.lm_prefill(LM, toks, length)
    exp = model.lm_prefill_ref(LM, toks, length)
    assert logits.shape == (b, C["vocab"])
    assert kv.shape == (
        C["n_layers"], 2, b, C["n_heads"], C["max_seq"], C["d_head"],
    )
    np.testing.assert_allclose(logits, exp, rtol=5e-4, atol=5e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 20))
def test_decode_consistent_with_prefill(seed, n):
    """logits(prefill(t_0..t_n)) == logits(prefill(t_0..t_{n-1}) + decode(t_n))."""
    rng = np.random.default_rng(seed)
    b, s = 1, C["max_seq"]
    toks = _tokens(rng, b, s)
    long_logits, _ = model.lm_prefill(LM, toks, jnp.asarray([n + 1], jnp.int32))
    short_logits, kv = model.lm_prefill(LM, toks, jnp.asarray([n], jnp.int32))
    step_logits, kv2 = model.lm_decode_step(
        LM, kv, toks[:, n], jnp.asarray([n], jnp.int32)
    )
    np.testing.assert_allclose(step_logits, long_logits, rtol=2e-3, atol=2e-3)
    assert kv2.shape == kv.shape


def test_decode_chain_matches_prefill():
    """Decoding 3 teacher-forced steps tracks prefill at each length."""
    rng = np.random.default_rng(7)
    toks = _tokens(rng, 1, C["max_seq"])
    _, kv = model.lm_prefill(LM, toks, jnp.asarray([4], jnp.int32))
    for i in range(4, 7):
        logits, kv = model.lm_decode_step(
            LM, kv, toks[:, i], jnp.asarray([i], jnp.int32)
        )
        exp, _ = model.lm_prefill(LM, toks, jnp.asarray([i + 1], jnp.int32))
        np.testing.assert_allclose(logits, exp, rtol=5e-3, atol=5e-3)


def test_embedder_unit_norm_and_padding_invariance():
    rng = np.random.default_rng(1)
    b, s = 8, C["embed_seq"]
    toks = np.asarray(_tokens(rng, b, s))
    length = jnp.asarray([s // 2] * b, jnp.int32)
    e1 = model.embed(EMB, jnp.asarray(toks), length)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(e1), axis=1), 1.0, rtol=1e-5
    )
    # Garbage in the padded region must not change the embedding.
    toks2 = toks.copy()
    toks2[:, s // 2:] = 255
    e2 = model.embed(EMB, jnp.asarray(toks2), length)
    np.testing.assert_allclose(e1, e2, rtol=1e-6, atol=1e-6)


def test_embedder_distinguishes_inputs():
    rng = np.random.default_rng(2)
    toks = _tokens(rng, 2, C["embed_seq"])
    length = jnp.asarray([C["embed_seq"]] * 2, jnp.int32)
    e = np.asarray(model.embed(EMB, toks, length))
    assert np.abs(e[0] - e[1]).max() > 1e-3


def test_classifier_shapes_and_determinism():
    rng = np.random.default_rng(3)
    emb = jnp.asarray(rng.normal(size=(8, C["embed_dim"])), jnp.float32)
    l1 = model.classify(CLS, emb)
    l2 = model.classify(CLS, emb)
    assert l1.shape == (8, C["n_classes"])
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_classifier_covers_all_classes():
    """Over random embeddings the argmax should hit every class (no dead head)."""
    rng = np.random.default_rng(4)
    emb = jnp.asarray(rng.normal(size=(256, C["embed_dim"])), jnp.float32)
    emb = emb / jnp.linalg.norm(emb, axis=1, keepdims=True)
    preds = np.asarray(model.classify(CLS, emb)).argmax(axis=1)
    assert set(preds.tolist()) == {0, 1, 2}
